//! End-to-end pins for the memory-aware pipeline (profiler → memory
//! model → shortlist → BO inside the shortlist): golden profiling
//! traces per Table II job, shortlist-correctness properties against
//! the planner's documented semantics, engine/direct search parity,
//! suspend/resume bit-identity at every round boundary, and the
//! catalog-scale acceptance run (`generated:1000`).
//!
//! The golden trace test is snapshot-style: the first run on a machine
//! writes `tests/golden/profile_traces_seed7.txt` (commit it); later
//! runs compare bit-for-bit, so any drift in the profiler, the sample
//! controller, or the model fit fails loudly. `--ignored` runs the
//! generator that prints the table for manual inspection.

use ruya::bayesopt::BoParams;
use ruya::coordinator::{
    MemoryPipeline, SearchPlan, SessionEngine, SessionState, THRESHOLDS,
};
use ruya::memmodel::{MemCategory, MemoryModel};
use ruya::searchspace::SearchSpace;
use ruya::workload::{evaluation_jobs, JobCostTable, JobInstance, MemBehavior};
use std::path::PathBuf;

const GOLDEN_SEED: u64 = 7;

fn job(label: &str) -> JobInstance {
    evaluation_jobs().into_iter().find(|j| j.label() == label).expect("known job label")
}

/// One deterministic snapshot line per job: every f64 as raw bits, so
/// the comparison is exact, not approximate.
fn profile_trace_line(pipeline: &MemoryPipeline, job: &JobInstance) -> String {
    let profile = pipeline.runner.profile_job(job, GOLDEN_SEED);
    let m = &profile.model;
    let readings: Vec<String> = m
        .readings
        .iter()
        .map(|(x, y)| format!("{:016x}:{:016x}", x.to_bits(), y.to_bits()))
        .collect();
    format!(
        "{}\t{}\t{:016x}\t{:016x}\t{:016x}\t{}",
        job.label(),
        m.category.name(),
        m.slope_gb_per_gb.to_bits(),
        m.intercept_gb.to_bits(),
        m.r2.to_bits(),
        readings.join(",")
    )
}

fn golden_snapshot() -> String {
    let pipeline = MemoryPipeline::native();
    let mut lines: Vec<String> = evaluation_jobs()
        .iter()
        .map(|j| profile_trace_line(&pipeline, j))
        .collect();
    lines.push(String::new()); // trailing newline
    lines.join("\n")
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/profile_traces_seed7.txt")
}

#[test]
fn golden_profile_traces_pin_readings_and_fit_bit_exact() {
    let snapshot = golden_snapshot();
    let path = golden_path();
    match std::fs::read_to_string(&path) {
        Ok(expected) => {
            for (k, (got, want)) in snapshot.lines().zip(expected.lines()).enumerate() {
                assert_eq!(
                    got, want,
                    "profiling trace drifted from the golden snapshot at line {} \
                     (regenerate by deleting {} if the change is intentional)",
                    k + 1,
                    path.display()
                );
            }
            assert_eq!(
                snapshot.lines().count(),
                expected.lines().count(),
                "golden snapshot line count changed"
            );
        }
        Err(_) => {
            // Bootstrap: first run on this machine writes the snapshot.
            std::fs::create_dir_all(path.parent().unwrap()).expect("golden dir");
            std::fs::write(&path, &snapshot).expect("write golden snapshot");
            eprintln!("bootstrapped golden snapshot at {} — commit it", path.display());
        }
    }
}

#[test]
#[ignore = "generator: prints the golden profiling table for manual regeneration"]
fn print_golden_profile_traces() {
    print!("{}", golden_snapshot());
}

#[test]
fn golden_profiles_are_reproducible_bit_for_bit() {
    // The snapshot mechanism is only sound if two in-process runs agree
    // exactly — the profiler and fit must be bit-deterministic per seed.
    let pipeline = MemoryPipeline::native();
    for j in evaluation_jobs() {
        let a = profile_trace_line(&pipeline, &j);
        let b = profile_trace_line(&pipeline, &j);
        assert_eq!(a, b, "{}: profiling is not deterministic", j.label());
    }
}

#[test]
fn golden_categories_recover_the_ground_truth_per_job() {
    // Table I per-job pin at the golden seed: the profiler must recover
    // each job's true memory behavior (Noisy ground truth lands in the
    // paper's "unclear" band).
    let pipeline = MemoryPipeline::native();
    for j in evaluation_jobs() {
        let profile = pipeline.runner.profile_job(&j, GOLDEN_SEED);
        let expect = match j.algo.mem_behavior {
            MemBehavior::Linear => MemCategory::Linear,
            MemBehavior::Flat => MemCategory::Flat,
            MemBehavior::Noisy => MemCategory::Unclear,
        };
        assert_eq!(profile.model.category, expect, "{}", j.label());
        assert_eq!(profile.model.readings.len(), 5, "{}: expected 5 readings", j.label());
        let xs: Vec<f64> = profile.model.readings.iter().map(|r| r.0).collect();
        assert!(xs.windows(2).all(|w| w[0] < w[1]), "{}: sample sizes not increasing", j.label());
        assert!(
            profile.model.readings.iter().all(|r| r.1 > 0.0),
            "{}: non-positive peak reading",
            j.label()
        );
        assert!((0.0..=1.0).contains(&profile.model.r2), "{}: r2 {}", j.label(), profile.model.r2);
    }
}

// ---------------------------------------------------------------------
// Shortlist correctness properties (§III-D semantics, exact).
// ---------------------------------------------------------------------

/// The shortlist must be exactly what the planner's documented §III-D
/// semantics prescribe for the model's category — not merely a subset.
fn assert_shortlist_semantics(pipeline: &MemoryPipeline, model: &MemoryModel, input_gb: f64) {
    let space = &pipeline.runner.space;
    let planner = &pipeline.runner.planner;
    let s = pipeline.shortlist_for(model, input_gb);

    assert!(!s.indices.is_empty(), "empty shortlist");
    assert_eq!(s.catalog_len, space.len());
    assert!(s.indices.windows(2).all(|w| w[0] < w[1]), "shortlist not strictly ascending");
    assert!(s.indices.iter().all(|&i| i < space.len()), "shortlist index out of catalog");

    match s.category {
        MemCategory::Unclear => {
            let all: Vec<usize> = (0..space.len()).collect();
            assert_eq!(s.indices, all, "unclear must keep the full space");
            assert!(!s.engaged());
        }
        MemCategory::Flat => {
            let mut expect = space.lowest_memory_configs(planner.flat_priority_len(space.len()));
            expect.sort_unstable();
            assert_eq!(s.indices, expect, "flat shortlist != low-memory priority group");
        }
        MemCategory::Linear => {
            let req = s.requirement_gb.expect("linear shortlist carries a requirement");
            assert!((req - model.estimate_requirement_gb(input_gb)).abs() < 1e-12);
            let need = req * (1.0 + planner.leeway);
            let admissible = space.with_usable_memory_at_least(need);
            if admissible.is_empty() {
                let mut expect = space.memory_extremes(planner.extremes_fraction);
                expect.sort_unstable();
                assert_eq!(s.indices, expect, "oversized requirement must fall back to extremes");
            } else {
                assert_eq!(s.indices, admissible, "linear shortlist != admissible set");
                // Completeness + soundness against the leeway-adjusted
                // threshold: every config at/above `need` is in, none
                // below it is.
                for i in 0..space.len() {
                    let inside = s.indices.binary_search(&i).is_ok();
                    assert_eq!(
                        inside,
                        space.config(i).usable_memory_gb() >= need,
                        "config {i} on the wrong side of the {need:.1} GB admissibility line"
                    );
                }
            }
        }
    }
}

fn synthetic_models() -> Vec<(MemoryModel, f64)> {
    let line = |slope: f64| -> MemoryModel {
        let readings: Vec<(f64, f64)> = (1..=5).map(|k| (k as f64, slope * k as f64)).collect();
        MemoryModel::fit(&readings)
    };
    let flat =
        MemoryModel::fit(&[(1.0, 1.2), (2.0, 1.15), (3.0, 1.22), (4.0, 1.18), (5.0, 1.2)]);
    let unclear =
        MemoryModel::fit(&[(1.0, 2.0), (2.0, 7.0), (3.0, 6.0), (4.0, 14.0), (5.0, 10.0)]);
    vec![
        (line(0.001), 8.4),   // tiny requirement: whole space qualifies
        (line(0.5), 120.0),   // moderate requirement
        (line(2.5), 201.2),   // K-Means/bigdata-like
        (line(2.5), 301.6),   // oversized on the scout space -> extremes
        (line(40.0), 500.0),  // oversized everywhere
        (flat, 150.0),
        (unclear, 150.0),
    ]
}

#[test]
fn shortlists_match_planner_semantics_on_scout_and_generated_catalogs() {
    for space in [
        SearchSpace::scout(),
        SearchSpace::generated(0x5417, 300),
        SearchSpace::generated(0x5417, 1000),
    ] {
        let pipeline = MemoryPipeline::new(
            ruya::coordinator::ExperimentRunner::native().with_space(space),
        );
        // Synthetic models covering every category and fallback branch.
        for (model, input_gb) in synthetic_models() {
            assert_shortlist_semantics(&pipeline, &model, input_gb);
        }
        // And the real fitted models of all 16 jobs.
        for j in evaluation_jobs() {
            let profile = pipeline.runner.profile_job(&j, GOLDEN_SEED);
            assert_shortlist_semantics(&pipeline, &profile.model, j.input_gb);
        }
    }
}

#[test]
fn engaged_shortlists_contain_the_optimum_on_the_scout_space() {
    // The paper's premise behind narrowing: for linear- and flat-memory
    // jobs the cost-optimal configuration is memory-suitable, so the
    // shortlist keeps it and BO inside the shortlist loses nothing.
    let pipeline = MemoryPipeline::native();
    for j in evaluation_jobs() {
        let (_, shortlist, _) = pipeline.shortlist_job(&j, GOLDEN_SEED);
        let table = JobCostTable::build(&pipeline.runner.sim, &j, &pipeline.runner.space);
        let best_in_shortlist = shortlist
            .indices
            .iter()
            .map(|&i| table.normalized[i])
            .fold(f64::INFINITY, f64::min);
        assert!(
            best_in_shortlist <= 1.0 + 1e-9,
            "{}: optimum outside the {} shortlist (best inside: {best_in_shortlist})",
            j.label(),
            shortlist.category.name()
        );
    }
}

#[test]
fn narrowed_argmin_not_worse_than_full_space_at_equal_budget() {
    // At an exhaustive equal budget the narrowed search's best cost can
    // never be worse than the full search's: both reach the optimum
    // (the shortlist contains it — pinned above), narrowed sooner.
    let pipeline = MemoryPipeline::native();
    let budget = pipeline.runner.space.len();
    let params = BoParams { max_iters: budget, ..Default::default() };
    for j in evaluation_jobs() {
        let (_, shortlist, _) = pipeline.shortlist_job(&j, GOLDEN_SEED);
        let table = JobCostTable::build(&pipeline.runner.sim, &j, &pipeline.runner.space);
        let rep_seed = GOLDEN_SEED ^ j.job_id;
        let narrowed = pipeline
            .runner
            .run_one_params(&table, &shortlist.plan(), rep_seed, &params)
            .expect("narrowed search");
        let full = pipeline
            .runner
            .run_one_params(
                &table,
                &SearchPlan::unpartitioned(&pipeline.runner.space),
                rep_seed,
                &params,
            )
            .expect("full search");
        let (nb, fb) = (narrowed.best_after(budget), full.best_after(budget));
        assert!(
            nb <= fb + 1e-12,
            "{}: narrowed argmin {nb} worse than full-space {fb} at equal budget",
            j.label()
        );
        assert!(narrowed.tried.len() <= shortlist.indices.len());
    }
}

// ---------------------------------------------------------------------
// Pipeline sessions: engine parity and suspend/resume determinism.
// ---------------------------------------------------------------------

#[test]
fn pipeline_narrowed_search_matches_direct_restricted_search_bit_for_bit() {
    // run_job drives the narrowed search through the SessionEngine; the
    // engine must reproduce the one-shot run_search trace exactly.
    let pipeline = MemoryPipeline::native();
    let budget = 24usize;
    let params = BoParams { max_iters: budget, ..Default::default() };
    for label in ["K-Means Spark huge", "Terasort Hadoop bigdata", "Lin. Regr. Spark huge"] {
        let j = job(label);
        let mut engine = SessionEngine::new(1);
        let out = pipeline.run_job(&mut engine, &j, GOLDEN_SEED, budget).expect("pipeline");
        let (_, shortlist, _) = pipeline.shortlist_job(&j, GOLDEN_SEED);
        let table = JobCostTable::build(&pipeline.runner.sim, &j, &pipeline.runner.space);
        let direct = pipeline
            .runner
            .run_one_params(&table, &shortlist.plan(), GOLDEN_SEED ^ j.job_id, &params)
            .expect("direct search");
        assert_eq!(out.narrowed.tried, direct.tried, "{label}: picks diverged");
        assert_eq!(
            out.narrowed.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
            direct.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
            "{label}: cost bits diverged"
        );
        assert_eq!(out.narrowed.stop_after, direct.stop_after, "{label}");
    }
}

#[test]
fn pipeline_sessions_suspend_and_resume_bit_identically_at_every_round() {
    let pipeline = MemoryPipeline::native();
    let j = job("K-Means Spark huge");
    let params = BoParams { max_iters: 12, ..Default::default() };
    let seed = GOLDEN_SEED ^ j.job_id;

    // Uninterrupted reference, counting engine rounds.
    let (reference, rounds, ref_phases) = {
        let mut engine = SessionEngine::new(1);
        let (handle, shortlist) =
            pipeline.register_job_with_engine(&mut engine, &j, GOLDEN_SEED).expect("register");
        assert!(shortlist.engaged(), "K-Means must narrow the scout space");
        let sid = engine.open(handle, seed, params).expect("open");
        let mut rounds = 0usize;
        while engine.step_all().expect("step") > 0 {
            rounds += 1;
        }
        (engine.outcome(sid).expect("reference outcome"), rounds, shortlist.phases())
    };
    assert!(rounds >= 12, "search too short to cut meaningfully ({rounds} rounds)");

    for cut in 0..=rounds {
        // Run `cut` rounds, suspend, serialize, resume in a FRESH engine.
        let mut engine = SessionEngine::new(1);
        let (handle, shortlist) =
            pipeline.register_job_with_engine(&mut engine, &j, GOLDEN_SEED).expect("register");
        let sid = engine.open(handle, seed, params).expect("open");
        for _ in 0..cut {
            engine.step_all().expect("step");
        }
        let state = engine.suspend(sid).expect("suspend");
        // The shortlist indices ARE the serialized phase plan.
        assert_eq!(state.phases, shortlist.phases(), "cut {cut}: state lost the shortlist");
        assert_eq!(state.phases, ref_phases, "cut {cut}");
        let decoded = SessionState::decode(&state.encode())
            .unwrap_or_else(|e| panic!("cut {cut}: decode failed: {e:#}"));

        let mut fresh = SessionEngine::new(1);
        pipeline.register_job_with_engine(&mut fresh, &j, GOLDEN_SEED).expect("re-register");
        let rid = fresh.resume(&decoded).unwrap_or_else(|e| panic!("cut {cut}: resume: {e:#}"));
        fresh.run_all().expect("run resumed");

        let out = fresh.outcome(rid).expect("resumed outcome");
        assert_eq!(out.tried, reference.tried, "cut {cut}: picks diverged");
        assert_eq!(
            out.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
            reference.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
            "cut {cut}: cost bits diverged"
        );
        assert_eq!(out.stop_after, reference.stop_after, "cut {cut}");
        assert_eq!(out.phase_starts, reference.phase_starts, "cut {cut}");
    }
}

// ---------------------------------------------------------------------
// Catalog-scale acceptance (the `ruya pipeline --space generated:1000`
// run of the issue's acceptance criteria).
// ---------------------------------------------------------------------

#[test]
fn narrowing_beats_full_catalog_search_for_linear_jobs_at_generated_1000() {
    let pipeline = MemoryPipeline::new(
        ruya::coordinator::ExperimentRunner::native()
            .with_space(SearchSpace::generated(0xC0FFEE, 1000)),
    );
    let budget = 96usize;
    let mut engine = SessionEngine::new(1);
    // The two most strongly narrowed linear Table II jobs on this catalog
    // (largest admissible-set reduction), compared at several search
    // seeds: each (job, seed) pair races the narrowed search against the
    // full catalog at the identical seed, and the verdict is the seed-
    // averaged total — one lucky full-catalog trajectory cannot decide it.
    let jobs = [job("Naive Bayes Spark bigdata"), job("K-Means Spark bigdata")];
    let seeds = [0xC0FFEEu64, 0xBADC0DE, 0x5EED5];
    let spend = |it: Option<usize>| it.unwrap_or(budget + 1);
    let mut narrowed_total = 0usize;
    let mut full_total = 0usize;
    let mut strict_win = false;
    for j in &jobs {
        for &seed in &seeds {
            let out = pipeline.run_job(&mut engine, j, seed, budget).expect("pipeline run");
            assert_eq!(out.category, MemCategory::Linear, "{}", j.label());
            assert!(out.engaged(), "{}: shortlist did not engage at catalog scale", j.label());
            let (n, f) = (out.narrowed_iters_to(THRESHOLDS[1]), out.full_iters_to(THRESHOLDS[1]));
            narrowed_total += spend(n);
            full_total += spend(f);
            if let Some(n) = n {
                if f.map_or(true, |f| n < f) {
                    strict_win = true;
                }
            }
        }
    }
    assert!(
        narrowed_total < full_total,
        "narrowed searches spent {narrowed_total} executions to cost <= 1.1 vs {full_total} \
         for the full catalog — narrowing bought nothing"
    );
    assert!(strict_win, "no linear job reached cost <= 1.1 in strictly fewer iterations");
}
