//! Parallel evaluation-engine tests: serial-vs-parallel bit-equivalence
//! of the Table II aggregates, and the windowed-history (`max_obs`)
//! search path end-to-end on the native backend.

use ruya::bayesopt::{run_search, BoParams, NativeBackend};
use ruya::coordinator::{ExperimentConfig, ExperimentRunner, SearchPlan};
use ruya::util::rng::Pcg64;
use ruya::workload::{evaluation_jobs, ClusterSim, JobCostTable, JobInstance};

fn job(label: &str) -> JobInstance {
    evaluation_jobs().into_iter().find(|j| j.label() == label).unwrap()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The acceptance contract: the reps=8 Table II experiment produces
/// bit-identical `iters_to` / `best_curve` / `cum_curve` on 1 and N
/// threads (same `ExperimentConfig`, only the worker count differs).
#[test]
fn parallel_engine_is_bit_identical_to_serial() {
    let cfg = ExperimentConfig { reps: 8, seed: 42, curve_len: 30 };
    // One two-phase (flat) job and one linear job cover both plan shapes.
    for label in ["Terasort Hadoop bigdata", "K-Means Spark huge"] {
        let serial =
            ExperimentRunner::native().with_threads(1).compare_job(&job(label), &cfg).unwrap();
        for threads in [3usize, 8] {
            let par = ExperimentRunner::native()
                .with_threads(threads)
                .compare_job(&job(label), &cfg)
                .unwrap();
            for (which, a, b) in [
                ("cherrypick", &serial.cherrypick, &par.cherrypick),
                ("ruya", &serial.ruya, &par.ruya),
            ] {
                assert_eq!(
                    bits(&a.iters_to),
                    bits(&b.iters_to),
                    "{label}/{which} iters_to diverged at {threads} threads"
                );
                assert_eq!(
                    bits(&a.best_curve),
                    bits(&b.best_curve),
                    "{label}/{which} best_curve diverged at {threads} threads"
                );
                assert_eq!(
                    bits(&a.cum_curve),
                    bits(&b.cum_curve),
                    "{label}/{which} cum_curve diverged at {threads} threads"
                );
                assert_eq!(
                    a.mean_stop.to_bits(),
                    b.mean_stop.to_bits(),
                    "{label}/{which} mean_stop diverged at {threads} threads"
                );
            }
        }
    }
}

/// Enforced-stop aggregation shards identically.
#[test]
fn stop_quality_parallel_matches_serial() {
    let cfg = ExperimentConfig { reps: 8, seed: 7, curve_len: 10 };
    let j = job("Join Spark huge");
    let run = |threads: usize| {
        let runner = ExperimentRunner::native().with_threads(threads);
        let table = JobCostTable::build(&runner.sim, &j, &runner.space);
        let plan = SearchPlan::unpartitioned(&runner.space);
        runner.stop_quality(&table, &plan, &cfg, 0x5EED).unwrap()
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.mean_stop_iters.to_bits(), b.mean_stop_iters.to_bits());
    assert_eq!(a.mean_best_cost.to_bits(), b.mean_best_cost.to_bits());
    assert_eq!(a.frac_optimal.to_bits(), b.frac_optimal.to_bits());
    assert_eq!(a.mean_search_spend.to_bits(), b.mean_search_spend.to_bits());
}

/// More workers than repetitions must not panic or change results.
#[test]
fn more_workers_than_reps_is_fine() {
    let cfg = ExperimentConfig { reps: 3, seed: 11, curve_len: 10 };
    let j = job("Lin. Regr. Spark huge");
    let serial = ExperimentRunner::native().with_threads(1).compare_job(&j, &cfg).unwrap();
    let par = ExperimentRunner::native().with_threads(16).compare_job(&j, &cfg).unwrap();
    assert_eq!(bits(&serial.cherrypick.iters_to), bits(&par.cherrypick.iters_to));
    assert_eq!(bits(&serial.ruya.iters_to), bits(&par.ruya.iters_to));
}

/// Job-level sharding: `run_table2` splits all 16 jobs × 2 methods ×
/// reps searches across workers as one flat task list, so even reps=2
/// exercises multi-worker sharding — and every aggregate must stay
/// bit-identical to the single-threaded run, per job and overall.
#[test]
fn run_table2_job_sharding_is_bit_identical() {
    let cfg = ExperimentConfig { reps: 2, seed: 9, curve_len: 20 };
    let serial = ExperimentRunner::native().with_threads(1).run_table2(&cfg).unwrap();
    let par = ExperimentRunner::native().with_threads(8).run_table2(&cfg).unwrap();
    assert_eq!(serial.jobs.len(), par.jobs.len());
    for (a, b) in serial.jobs.iter().zip(&par.jobs) {
        assert_eq!(a.label, b.label, "job order changed under sharding");
        assert_eq!(bits(&a.cherrypick.iters_to), bits(&b.cherrypick.iters_to), "{}", a.label);
        assert_eq!(bits(&a.ruya.iters_to), bits(&b.ruya.iters_to), "{}", a.label);
        assert_eq!(bits(&a.cherrypick.best_curve), bits(&b.cherrypick.best_curve));
        assert_eq!(bits(&a.ruya.cum_curve), bits(&b.ruya.cum_curve));
    }
    assert_eq!(bits(&serial.mean_cherrypick), bits(&par.mean_cherrypick));
    assert_eq!(bits(&serial.mean_ruya), bits(&par.mean_ruya));
    assert_eq!(bits(&serial.mean_quotient), bits(&par.mean_quotient));
}

/// The flat job-sharded `run_table2` path must agree bit-for-bit with
/// composing the per-job `compare_job` path (same seeds, same folds).
#[test]
fn run_table2_matches_compare_job_composition() {
    let cfg = ExperimentConfig { reps: 2, seed: 5, curve_len: 15 };
    let runner = ExperimentRunner::native().with_threads(4);
    let table2 = runner.run_table2(&cfg).unwrap();
    for row in table2.jobs.iter().take(3) {
        let jc = runner
            .compare_job(&job(&row.label), &cfg)
            .unwrap();
        assert_eq!(bits(&row.cherrypick.iters_to), bits(&jc.cherrypick.iters_to), "{}", row.label);
        assert_eq!(bits(&row.ruya.iters_to), bits(&jc.ruya.iters_to), "{}", row.label);
        assert_eq!(bits(&row.cherrypick.cum_curve), bits(&jc.cherrypick.cum_curve));
        assert_eq!(bits(&row.ruya.best_curve), bits(&jc.ruya.best_curve));
        assert_eq!(row.cherrypick.mean_stop.to_bits(), jc.cherrypick.mean_stop.to_bits());
    }
}

/// End-to-end windowed-history search over the real 69-configuration
/// space and a real job's cost table: the search must keep functioning
/// once the history exceeds the backend capacity (sliding window), still
/// exhaust the space, find the optimum, and record an execution-count
/// stopping point.
#[test]
fn windowed_history_search_end_to_end() {
    let space = ruya::searchspace::SearchSpace::scout();
    let features = space.feature_matrix();
    let m = space.len();
    let d = ruya::searchspace::N_FEATURES;
    let j = job("K-Means Spark huge");
    let sim = ClusterSim::default();
    let table = JobCostTable::build(&sim, &j, &space);
    let phases = vec![(0..m).collect::<Vec<usize>>()];
    let params = BoParams { max_iters: m, ..Default::default() };

    let mut backend = ruya::testkit::CappedBackend::new(NativeBackend::new(), 16);
    let mut rng = Pcg64::from_seed(99);
    let costs = &table.normalized;
    let mut oracle = |i: usize| costs[i];
    let out =
        run_search(&features, m, d, &phases, &mut oracle, &mut backend, &mut rng, &params)
            .expect("windowed search");

    assert_eq!(out.tried.len(), m, "windowed search must still exhaust the space");
    assert!(out.first_within(1.0 + 1e-9).is_some(), "optimum never tried");
    // The trace replays the cost table faithfully.
    for (&idx, &cost) in out.tried.iter().zip(&out.costs) {
        assert_eq!(cost, table.normalized[idx]);
    }
    // A recorded stopping point counts executions, which may exceed the
    // conditioning capacity.
    if let Some(stop) = out.stop_after {
        assert!(stop >= params.min_obs_for_stop);
        assert!(stop <= m);
    }
}
