//! §Transfer bench: warm-started BO from cross-job behavior clusters vs
//! the cold narrowed search, under leave-one-out — each held-out job is
//! warmed from a [`TransferStore`] built from the *other* jobs only, so
//! a job can never warm itself (belt-and-braces: its label is also
//! passed as the exclusion to `warm_start`).
//!
//! Per job and seed the race runs the memory-aware pipeline cold
//! (profiler → model → shortlist → BO) over the scout catalog, then
//! re-runs the narrowed search from the transferred prior at the same
//! seed and budget, and reports iterations-to-(cost ≤ 1.1) for both
//! legs.
//!
//! `--smoke` (the CI mode) asserts the transfer layer's contract:
//! every held-out job finds applicable evidence, the warm leg is no
//! worse than the cold leg in total executions-to-threshold over the
//! full 16-job × 2-seed matrix (not-reached counts as budget+1), at
//! least one job wins strictly, and a store holding only the job
//! itself yields no warm start once that label is excluded.
//!
//! [`TransferStore`]: ruya::coordinator::TransferStore

#[path = "harness.rs"]
mod harness;

use ruya::bayesopt::BoParams;
use ruya::coordinator::{signature, MemoryPipeline, SessionEngine, TransferStore, THRESHOLDS};
use ruya::workload::evaluation_jobs;
use std::time::Instant;

const SEED: u64 = 0xC0FFEE;

/// One job's cold-vs-warm verdict at a given seed.
struct Leg {
    label: String,
    /// Cold narrowed iterations to cost ≤ 1.1 (1-based; None = never).
    cold: Option<usize>,
    /// Warm iterations to the same threshold; equals `cold` when no
    /// transferable evidence applied (a tie by definition).
    warm: Option<usize>,
    /// Whether a warm start was actually mined and run.
    warmed: bool,
    /// Seeds the prior offered (before the in-phase filter).
    seeds: usize,
}

/// Race every evaluation job cold-vs-warm at one seed. The cold leg
/// registers each job on a shared engine and absorbs nothing; the warm
/// leg then rebuilds, per held-out job, a store from the other jobs'
/// cold narrowed outcomes and reruns the narrowed search from that
/// prior at the identical seed and budget.
fn race(seed: u64) -> Vec<Leg> {
    let pipeline = MemoryPipeline::native();
    let space = &pipeline.runner.space;
    let budget = space.len();
    let jobs = evaluation_jobs();
    let mut engine = SessionEngine::new(1);

    // Cold pass: signatures + cold narrowed outcomes for every job.
    let mut sigs = Vec::new();
    let mut cold = Vec::new();
    for job in &jobs {
        let profile = pipeline.runner.profile_job(job, seed);
        sigs.push(signature(job, &profile.model));
        let out = pipeline.run_job(&mut engine, job, seed, budget).expect("cold pipeline run");
        cold.push(out.narrowed);
    }

    // Warm pass under true leave-one-out.
    let mut legs = Vec::new();
    for (j, job) in jobs.iter().enumerate() {
        let mut store = TransferStore::default();
        for (k, outcome) in cold.iter().enumerate() {
            if k != j {
                store.absorb(&sigs[k], space, outcome);
            }
        }
        let label = job.label();
        let cold_iters = cold[j].first_within(THRESHOLDS[1]);
        match store.warm_start(&sigs[j], space, Some(&label)) {
            Some(warm) => {
                let handle = engine.job_index(&label).expect("cold pass registered the job");
                let params = BoParams { max_iters: budget, ..Default::default() };
                let sid = engine
                    .open_warm(handle, seed ^ job.job_id, params, &warm)
                    .expect("open warm session");
                engine.run_all().expect("run warm session");
                let outcome = engine.outcome(sid).expect("warm session outcome");
                legs.push(Leg {
                    label,
                    cold: cold_iters,
                    warm: outcome.first_within(THRESHOLDS[1]),
                    warmed: true,
                    seeds: warm.seeds.len(),
                });
            }
            None => legs.push(Leg {
                label,
                cold: cold_iters,
                warm: cold_iters,
                warmed: false,
                seeds: 0,
            }),
        }
    }
    legs
}

fn fmt_iters(it: Option<usize>) -> String {
    it.map_or_else(|| "-".to_string(), |k| k.to_string())
}

fn print_legs(seed: u64, legs: &[Leg]) {
    for leg in legs {
        let prior = if leg.warmed {
            format!("{} seeds offered", leg.seeds)
        } else {
            "cold (no evidence)".to_string()
        };
        println!(
            "  {:27} seed {seed:>9x}  cold<=1.1 {:>4}  warm<=1.1 {:>4}  {prior}",
            leg.label,
            fmt_iters(leg.cold),
            fmt_iters(leg.warm),
        );
    }
}

fn smoke() {
    harness::section("transfer smoke (CI guard, leave-one-out warm vs cold)");
    let t0 = Instant::now();
    let budget = MemoryPipeline::native().runner.space.len();
    let spend = |it: &Option<usize>| it.unwrap_or(budget + 1);

    let mut cold_total = 0usize;
    let mut warm_total = 0usize;
    let mut strict_win = false;
    let mut jobs_seen = 0usize;
    for &seed in &[SEED, SEED ^ 0xBADC0DE] {
        let legs = race(seed);
        print_legs(seed, &legs);
        for leg in &legs {
            assert!(
                leg.warmed,
                "{}: no transferable evidence despite 15 absorbed sibling jobs",
                leg.label
            );
            cold_total += spend(&leg.cold);
            warm_total += spend(&leg.warm);
            strict_win |= spend(&leg.warm) < spend(&leg.cold);
        }
        jobs_seen += legs.len();
    }
    assert_eq!(jobs_seen, 32, "expected the 16 evaluation jobs x 2 seeds");
    assert!(
        warm_total <= cold_total,
        "warm-started searches fell behind cold over the matrix: \
         {warm_total} vs {cold_total} total executions to cost <= 1.1"
    );
    assert!(
        strict_win,
        "no job reached cost <= 1.1 strictly sooner warm than cold"
    );

    // The leave-one-out guarantee itself: a store that only ever saw the
    // held-out job must refuse to warm it.
    let pipeline = MemoryPipeline::native();
    let job = &evaluation_jobs()[0];
    let profile = pipeline.runner.profile_job(job, SEED);
    let sig = signature(job, &profile.model);
    let mut engine = SessionEngine::new(1);
    let out = pipeline.run_job(&mut engine, job, SEED, budget).expect("pipeline run");
    let mut own = TransferStore::default();
    own.absorb(&sig, &pipeline.runner.space, &out.narrowed);
    assert!(
        own.warm_start(&sig, &pipeline.runner.space, Some(&job.label())).is_none(),
        "a job warmed itself through the label exclusion"
    );

    println!(
        "smoke ok: all 32 job-seed legs warmed, warm beats-or-ties cold \
         ({warm_total} vs {cold_total} executions to <=1.1, with a strict win), \
         self-transfer refused, in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }

    harness::section("cross-job transfer: leave-one-out warm vs cold narrowed search");
    for &seed in &[SEED, SEED ^ 0xBADC0DE] {
        let t0 = Instant::now();
        let legs = race(seed);
        print_legs(seed, &legs);
        let spend = |it: &Option<usize>| it.unwrap_or(usize::MAX);
        let wins = legs.iter().filter(|l| spend(&l.warm) < spend(&l.cold)).count();
        let ties = legs.iter().filter(|l| spend(&l.warm) == spend(&l.cold)).count();
        println!(
            "seed {seed:x}: warm wins {wins}, ties {ties}, losses {} of {} jobs  ({:.1}s)",
            legs.len() - wins - ties,
            legs.len(),
            t0.elapsed().as_secs_f64()
        );
    }
}
