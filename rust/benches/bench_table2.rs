//! Bench/regeneration target for **Table II** (iterations until a
//! configuration with normalized cost c is found, CherryPick vs Ruya):
//! runs a reduced-repetition version of the full experiment, times one
//! complete seeded search per method, and sweeps the parallel engine's
//! worker count for the searches/second throughput record.
//!
//! Full-scale (200-rep) numbers: `ruya table2 --reps 200 [--threads N]`
//! or `examples/full_reproduction.rs`; recorded in EXPERIMENTS.md.

#[path = "harness.rs"]
mod harness;

use ruya::coordinator::{ExperimentConfig, ExperimentRunner, SearchPlan};
use ruya::report;
use ruya::workload::{evaluation_jobs, JobCostTable};

fn main() {
    harness::section("Table II regeneration (25 reps, native backend)");
    let runner = ExperimentRunner::native();
    let cfg = ExperimentConfig { reps: 25, seed: 0xC0FFEE, curve_len: 48 };
    let result = runner.run_table2(&cfg).expect("experiment");
    println!("{}", report::render_table2(&result));
    println!(
        "paper means: CP 8.735/16.487/23.629, Ruya 3.307/6.627/11.631, quotient 37.9%/40.2%/49.2%"
    );

    harness::section("timing: one full seeded search (to exhaustion, 69 configs)");
    let job = evaluation_jobs().into_iter().find(|j| j.label() == "K-Means Spark huge").unwrap();
    let table = JobCostTable::build(&runner.sim, &job, &runner.space);
    let profile = runner.profile_job(&job, cfg.seed);
    let ruya_plan = runner.planner.plan(&profile.model, job.input_gb, &runner.space);
    let cp_plan = SearchPlan::unpartitioned(&runner.space);

    let mut seed = 0u64;
    harness::bench_fn("search to exhaustion [CherryPick]", || {
        seed += 1;
        std::hint::black_box(runner.run_one(&table, &cp_plan, seed).unwrap());
    });
    harness::bench_fn("search to exhaustion [Ruya]", || {
        seed += 1;
        std::hint::black_box(runner.run_one(&table, &ruya_plan, seed).unwrap());
    });

    // The acceptance record for the parallel engine: a Table-II slice
    // (4 jobs x 2 methods x 16 reps of full searches) at 1/2/4/8 worker
    // threads. Results are bit-identical across the sweep; only the
    // wall-clock moves.
    harness::section("Table II throughput: repetition sharding (searches/sec)");
    let slice = [
        "K-Means Spark huge",
        "Naive Bayes Spark huge",
        "Terasort Hadoop huge",
        "Join Spark bigdata",
    ];
    let jobs: Vec<_> = evaluation_jobs()
        .into_iter()
        .filter(|j| slice.contains(&j.label().as_str()))
        .collect();
    let sweep_cfg = ExperimentConfig { reps: 16, seed: 0xC0FFEE, curve_len: 48 };
    let searches = jobs.len() * 2 * sweep_cfg.reps;
    let mut serial_secs = None;
    for threads in [1usize, 2, 4, 8] {
        let sharded = ExperimentRunner::native().with_threads(threads);
        let secs = harness::bench_throughput(
            &format!("table2 slice ({} jobs), {threads} thread(s)", jobs.len()),
            || {
                for job in &jobs {
                    std::hint::black_box(sharded.compare_job(job, &sweep_cfg).unwrap());
                }
                searches
            },
        );
        match serial_secs {
            None => serial_secs = Some(secs),
            Some(base) => println!("{:44} speedup {:.2}x over serial", "", base / secs),
        }
    }
}
