//! Bench/regeneration target for **Table III** (memory profiling time per
//! job): regenerates the table and reports the simulated wall-clock
//! distribution against the paper's (mean ~565 s, 110..1292 s band).

#[path = "harness.rs"]
mod harness;

use ruya::coordinator::ExperimentRunner;
use ruya::report;

fn main() {
    harness::section("Table III regeneration (simulated profiling wall-clock)");
    let runner = ExperimentRunner::native();
    let summaries = runner.profile_all(0xC0FFEE);
    println!("{}", report::render_table3(&summaries));

    let times: Vec<f64> = summaries.iter().map(|s| s.profiling_time_s).collect();
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::MAX, f64::min);
    let max = times.iter().cloned().fold(0.0f64, f64::max);
    println!("measured: mean {mean:.0} s (paper 565 s), range {min:.0}..{max:.0} s (paper 110..1292 s)");
}
