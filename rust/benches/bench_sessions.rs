//! §Perf bench: the resident session engine — sessions/sec and p50/p99
//! step latency at 1k/10k/100k concurrent sessions multiplexed over one
//! shared scoring pool.
//!
//! `--smoke` (the CI mode) runs 64 concurrent sessions and *asserts*
//! (via `SessionStats`) that the admission layer batches concurrent
//! same-catalog decisions into shared fan-outs, that sessions share the
//! one process-global worker pool (zero per-session pool creations, and
//! live GP threads bounded by the pool width), and that
//! a suspend -> serialize -> deserialize -> resume round-trip performed
//! inside the bench rejoins the uninterrupted trace bit for bit — so
//! the optimizer-as-a-service layer cannot silently regress in CI.

#[path = "harness.rs"]
mod harness;

use ruya::bayesopt::BoParams;
use ruya::coordinator::{SessionEngine, SessionState};
use ruya::searchspace::SearchSpace;
use std::time::Instant;

fn synthetic_costs(space: &SearchSpace) -> Vec<f64> {
    (0..space.len()).map(|i| 0.5 + ((i * 37) % 101) as f64 / 101.0).collect()
}

fn two_phase(space: &SearchSpace) -> Vec<Vec<usize>> {
    let priority = space.lowest_memory_configs(10);
    let rest: Vec<usize> = (0..space.len()).filter(|i| !priority.contains(i)).collect();
    vec![priority, rest]
}

/// An engine over the scout catalog with `count` open sessions (seeds
/// deterministic per slot, so two engines built alike run alike).
fn engine_with_sessions(count: usize, width: usize, params: BoParams) -> SessionEngine {
    let space = SearchSpace::scout();
    let mut engine = SessionEngine::new(width);
    let job = engine
        .register_job("bench", &space, synthetic_costs(&space), two_phase(&space))
        .expect("register");
    for s in 0..count {
        engine.open(job, 0xBE7C ^ s as u64, params).expect("open");
    }
    engine
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    let pos = q * (sorted.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

fn run_scale(count: usize) {
    let params = BoParams { max_iters: 6, ..Default::default() };
    let mut engine = engine_with_sessions(count, 0, params);
    let t0 = Instant::now();
    // Per-round per-step latency samples: every step_all round advances
    // each live session once, so elapsed/stepped is the per-session step
    // cost of that round (execute rounds cheap, decide rounds pooled).
    let mut lat: Vec<f64> = Vec::new();
    loop {
        let t = Instant::now();
        let n = engine.step_all().expect("step");
        if n == 0 {
            break;
        }
        lat.push(t.elapsed().as_nanos() as f64 / n as f64);
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = engine.stats();
    assert_eq!(stats.sessions_finished as usize, count);
    println!(
        "{count:>7} sessions: {:>10.0} sessions/s  {:>11.0} steps/s  \
         step p50 {:>10}  p99 {:>10}  ({} rounds, {} batched decides)",
        count as f64 / secs,
        stats.steps as f64 / secs,
        harness::fmt_ns(percentile(&lat, 0.50)),
        harness::fmt_ns(percentile(&lat, 0.99)),
        lat.len(),
        stats.batched_decides
    );
}

fn smoke() {
    harness::section("session engine smoke (CI guard)");
    let params = BoParams { max_iters: 10, ..Default::default() };

    // Reference: the same 64 sessions run uninterrupted.
    let mut reference = engine_with_sessions(64, 2, params);
    reference.run_all().expect("reference run");

    let t0 = Instant::now();
    let mut engine = engine_with_sessions(64, 2, params);
    for _ in 0..4 {
        engine.step_all().expect("step");
    }
    // Suspend / serialize / deserialize / resume one session mid-flight.
    let victim = engine.session_ids()[10];
    let state = engine.suspend(victim).expect("suspend");
    let resumed = engine
        .resume(&SessionState::decode(&state.encode()).expect("decode"))
        .expect("resume");
    engine.run_all().expect("run");
    let secs = t0.elapsed().as_secs_f64().max(1e-9);

    let stats = engine.stats();
    assert!(
        stats.batched_decides > 0,
        "concurrent same-catalog decides never batched: {stats:?}"
    );
    assert_eq!(
        engine.session_backend_pool_creates(),
        0,
        "a session created its own worker pool instead of sharing the engine's"
    );
    // The thread-budget contract of the process-global pool: however
    // many engines, sessions and backends this process has run, the
    // parked GP worker threads never exceed the one shared pool's width.
    assert!(
        ruya::bayesopt::spawned_pool_threads() <= ruya::bayesopt::global_pool_width(),
        "GP threads exceeded the shared pool width: {} > {}",
        ruya::bayesopt::spawned_pool_threads(),
        ruya::bayesopt::global_pool_width()
    );
    assert_eq!((stats.suspends, stats.resumes), (1, 1), "round-trip not performed: {stats:?}");
    assert_eq!(stats.sessions_finished, 64);
    assert_eq!(stats.sessions_active, 0);

    // The round-trip rejoined the uninterrupted trace bit for bit.
    let a = engine.outcome(resumed).expect("resumed outcome");
    let b = reference.outcome(victim).expect("reference outcome");
    assert_eq!(a.tried, b.tried, "resumed picks diverged from the uninterrupted run");
    assert_eq!(
        a.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
        b.costs.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
        "resumed cost bits diverged from the uninterrupted run"
    );

    println!(
        "smoke ok: 64 sessions at {:.0} sessions/s, {} decides batched over {} fan-out \
         rounds, suspend/resume round-trip exact",
        64.0 / secs,
        stats.batched_decides,
        stats.fanout_rounds
    );
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }

    harness::section("single-session reference (open + run, scout catalog, 6 iters)");
    let params = BoParams { max_iters: 6, ..Default::default() };
    harness::bench_fn("engine open+run (1 session)", || {
        let mut e = engine_with_sessions(1, 1, params);
        while e.step_all().expect("step") > 0 {}
    });

    harness::section("session engine throughput (shared pool, batched decides)");
    for &count in &[1_000usize, 10_000, 100_000] {
        run_scale(count);
    }
}
