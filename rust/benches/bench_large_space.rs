//! §Perf bench: exact-vs-low-rank decide latency across search-space
//! sizes — the measurement behind the generated-catalog workload class.
//!
//! Sweeps `decide` (one GP fit + EI over all candidates) over
//! n_candidates ∈ {69 (scout), 1k, 5k (generated)} at small and large
//! observation counts, with the low-rank path forced off vs the Auto
//! policy, and reports each configuration's latency as a multiple of the
//! 69-config exact baseline.
//!
//! Regime note: each cell repeats `decide` on a *fixed* history, so the
//! exact path's factor/d2 caches are warm (a cache-hit refit plus
//! scoring) and the low-rank path's inducing cache serves every repeat
//! from its first full selection (an Unchanged delta — the incremental
//! refresh at its cheapest); the low-rank fit itself (two u x u
//! factorizations) still reruns per call. In the real search loop the
//! history grows every iteration, where the refresh's append path
//! replaces what used to be a full O(n·u·d) re-selection per fit.
//!
//! `--smoke` (the CI mode) runs tiny sizes only and *asserts* the
//! documented policy thresholds: the Nyström path engages above
//! `LOWRANK_CANDIDATE_THRESHOLD` (with enough observations) and the
//! exact path keeps serving everything below it.

#[path = "harness.rs"]
mod harness;

use ruya::bayesopt::{
    GpBackend, LowRankPolicy, NativeBackend, DECIDE_TILE, LOWRANK_CANDIDATE_THRESHOLD,
    LOWRANK_MIN_OBS,
};
use ruya::searchspace::SearchSpace;
use ruya::util::rng::Pcg64;

/// Synthetic observations over distinct space rows (cycling would create
/// duplicate rows, which the exact Gram tolerates but never needs here:
/// callers keep `n <= space.len()`).
fn observations(space: &SearchSpace, n: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n <= space.len());
    let mut rng = Pcg64::from_seed(42);
    let mut x = Vec::with_capacity(n * ruya::searchspace::N_FEATURES);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        x.extend(space.features(i));
        y.push(1.0 + rng.next_f64());
    }
    (x, y)
}

/// Median decide latency (ns) for one (space, n_obs, policy, gp-threads)
/// cell.
fn decide_latency(
    space: &SearchSpace,
    n: usize,
    policy: LowRankPolicy,
    gp_threads: usize,
    label: &str,
) -> f64 {
    let d = ruya::searchspace::N_FEATURES;
    let m = space.len();
    let features = space.feature_matrix();
    let (x, y) = observations(space, n);
    let cmask: Vec<bool> = (0..m).map(|i| i >= n).collect();
    let hyp = [0.5, 1.0, 1e-3];
    let mut backend = NativeBackend::new();
    backend.set_lowrank_policy(policy);
    backend.set_parallelism(gp_threads);
    let stats = harness::bench_fn(label, || {
        std::hint::black_box(
            backend.decide(&x, &y, n, d, &features, &cmask, m, hyp).unwrap(),
        );
    });
    stats.median()
}

fn latency_sweep() {
    harness::section("decide latency: exact vs low-rank across space sizes");
    println!(
        "(fixed-history cells: exact runs warm-cache, low-rank re-fits per call —\n \
         speedups are a lower bound on the low-rank advantage; see module docs)"
    );
    let scout = SearchSpace::scout();
    let spaces: Vec<(String, SearchSpace)> = vec![
        ("scout:69".into(), scout),
        ("generated:1000".into(), SearchSpace::generated(1, 1000)),
        ("generated:5000".into(), SearchSpace::generated(1, 5000)),
    ];
    // The acceptance baseline: the exact path on the 69-config space at
    // the same observation count the big spaces are measured at.
    let n_small = 48;
    let baseline = decide_latency(
        &spaces[0].1,
        n_small,
        LowRankPolicy::Off,
        1,
        "scout:69 exact (n=48)",
    );
    println!("    -> baseline: 69-config exact decide at n=48");

    for (name, space) in spaces.iter().skip(1) {
        for &n in &[n_small, 256usize] {
            let exact = decide_latency(
                space,
                n,
                LowRankPolicy::Off,
                1,
                &format!("{name} exact   (n={n:3})"),
            );
            let auto = decide_latency(
                space,
                n,
                LowRankPolicy::Auto,
                1,
                &format!("{name} auto    (n={n:3})"),
            );
            println!(
                "    -> {name} n={n:3}: exact {:.2}x baseline, auto {:.2}x baseline, \
                 lowrank speedup {:.2}x",
                exact / baseline,
                auto / baseline,
                exact / auto,
            );
        }
    }
}

/// The `--gp-threads` axis: one exact decide over the 5k-config catalog
/// (5 tiles) at pool widths 1/2/4/8 — the tile fan-out measurement.
/// Results are bit-identical across the axis (see the smoke guards);
/// only the latency moves.
fn decide_thread_sweep() {
    harness::section("exact decide across the GP worker pool (tile fan-out, generated:5000)");
    let space = SearchSpace::generated(1, 5000);
    let n = 64;
    let mut serial = 0.0;
    for &t in &[1usize, 2, 4, 8] {
        let med = decide_latency(
            &space,
            n,
            LowRankPolicy::Off,
            t,
            &format!("generated:5000 exact, gp-threads {t} (n={n})"),
        );
        if t == 1 {
            serial = med;
        } else {
            println!("    -> speedup at {t} gp-threads: {:.2}x", serial / med);
        }
    }
}

/// Functional guard (the whole point of `--smoke`): the documented
/// policy thresholds must route decides to the right path.
fn assert_policy_thresholds() {
    let d = ruya::searchspace::N_FEATURES;
    let hyp = [0.5, 1.0, 1e-3];

    // The smallest history the Auto policy genuinely approximates.
    let engaged = LOWRANK_MIN_OBS + 1;

    // Below the candidate threshold (the scout space): exact, always.
    let scout = SearchSpace::scout();
    let m = scout.len();
    assert!(m <= LOWRANK_CANDIDATE_THRESHOLD, "scout space unexpectedly large");
    let features = scout.feature_matrix();
    let (x, y) = observations(&scout, engaged.min(scout.len()));
    let n = engaged.min(scout.len());
    let cmask = vec![true; m];
    let mut b = NativeBackend::new();
    b.decide(&x, &y, n, d, &features, &cmask, m, hyp).unwrap();
    let s = b.decide_stats();
    assert_eq!(s.exact, 1, "small space must stay exact: {s:?}");
    assert_eq!(s.lowrank, 0, "small space must not engage low-rank: {s:?}");

    // Above the threshold with a long enough history: low-rank engages.
    let big = SearchSpace::generated(3, LOWRANK_CANDIDATE_THRESHOLD + 200);
    let mb = big.len();
    let fb = big.feature_matrix();
    let cb = vec![true; mb];
    let (xb, yb) = observations(&big, engaged);
    let mut b = NativeBackend::new();
    b.decide(&xb, &yb, engaged, d, &fb, &cb, mb, hyp).unwrap();
    let s = b.decide_stats();
    assert_eq!(s.lowrank, 1, "large space must engage low-rank: {s:?}");
    assert_eq!(s.exact, 0, "large space must not fall back silently: {s:?}");

    // Above the threshold but history within the inducing cap (low-rank
    // would be exact math at extra cost): exact.
    let (xs, ys) = observations(&big, LOWRANK_MIN_OBS);
    let mut b = NativeBackend::new();
    b.decide(&xs, &ys, LOWRANK_MIN_OBS, d, &fb, &cb, mb, hyp).unwrap();
    let s = b.decide_stats();
    assert_eq!(s.exact, 1, "within-cap decide must stay exact: {s:?}");

    println!("low-rank policy-threshold guard: OK");
}

/// Functional guard (runs in `--smoke` too): on a multi-tile space the
/// threaded decide must take the tile fan-out and match the serial tile
/// loop bit-for-bit.
fn assert_parallel_decide_engages() {
    let d = ruya::searchspace::N_FEATURES;
    let space = SearchSpace::generated(5, DECIDE_TILE + 300); // two tiles
    let n = 24; // past GP_POOL_MIN_OBS, so the fan-out clears the floor
    let m = space.len();
    let features = space.feature_matrix();
    let cmask = vec![true; m];
    let (x, y) = observations(&space, n);
    let hyp = [0.5, 1.0, 1e-3];
    let mut serial = NativeBackend::new();
    serial.set_lowrank_policy(LowRankPolicy::Off);
    serial.set_parallelism(1);
    let mut par = NativeBackend::new();
    par.set_lowrank_policy(LowRankPolicy::Off);
    par.set_parallelism(4);
    let ds = serial.decide(&x, &y, n, d, &features, &cmask, m, hyp).unwrap();
    let dp = par.decide(&x, &y, n, d, &features, &cmask, m, hyp).unwrap();
    for j in 0..m {
        assert!(ds.mu[j].to_bits() == dp.mu[j].to_bits(), "threaded mu[{j}] diverged");
        assert!(ds.var[j].to_bits() == dp.var[j].to_bits(), "threaded var[{j}] diverged");
        assert!(ds.ei[j].to_bits() == dp.ei[j].to_bits(), "threaded ei[{j}] diverged");
    }
    let s = par.decide_stats();
    assert!(s.parallel_decide_fanouts > 0, "decide tile fan-out never engaged: {s:?}");
    assert_eq!(serial.decide_stats().parallel_decide_fanouts, 0);
    println!("parallel decide-tile guard: OK ({s:?})");
}

/// Functional guard (runs in `--smoke` too): past its observation
/// threshold `nll_grid` must route to the Woodbury low-rank marginal —
/// and agree with the exact sweep in the `Z = X` reduction regime.
fn assert_lowrank_nll_routes() {
    let d = ruya::searchspace::N_FEATURES;
    let space = SearchSpace::generated(9, 80);
    let n = 40;
    let (x, y) = observations(&space, n);
    // Moderate-noise grid: the Z = X comparison divides a cancelling
    // quadratic form by σ², so the grid's smallest noise level would
    // amplify last-ulp error past a meaningful bound (the full grid's
    // serial-vs-threaded bit parity is pinned in tests/parallel_gp.rs).
    let grid = [[0.5, 1.0, 1e-2], [1.0, 1.0, 1e-2], [2.0, 1.0, 1e-1], [0.5, 1.0, 1e-1]];
    let mut routed = NativeBackend::new();
    routed.set_lowrank_nll_threshold(32); // lowered so the guard is cheap
    let a = routed.nll_grid(&x, &y, n, d, &grid).unwrap();
    let s = routed.decide_stats();
    assert_eq!(s.nll_lowrank, 1, "low-rank nll_grid routing never engaged: {s:?}");
    let mut exact = NativeBackend::new();
    let b = exact.nll_grid(&x, &y, n, d, &grid).unwrap();
    // n <= DEFAULT_MAX_INDUCING: FPS selects every observation, so the
    // DTC marginal reduces to the exact one (lowrank module docs).
    for (g, (va, vb)) in a.iter().zip(&b).enumerate() {
        assert!(
            (va - vb).abs() <= 1e-4 * va.abs().max(vb.abs()).max(1.0),
            "routed nll[{g}] drifted: {va} vs exact {vb}"
        );
    }
    println!("low-rank nll_grid routing guard: OK");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    assert_policy_thresholds();
    assert_parallel_decide_engages();
    assert_lowrank_nll_routes();
    if smoke {
        println!("\nsmoke mode: skipping the full latency sweep");
        return;
    }
    latency_sweep();
    decide_thread_sweep();
}
