//! Criterion-lite micro-benchmark harness shared by the bench targets
//! (the `criterion` crate is unavailable offline).
//!
//! Two modes per bench binary:
//!  * timing sections (`bench_fn`): warmup + N samples, report
//!    median/mean/p10/p90 wall-clock;
//!  * table/figure sections: regenerate the paper artifact and print it
//!    (the "bench" for a table is the harness that reproduces it).
//!
//! `cargo bench` passes `--bench` through; any other CLI args are
//! ignored so the binaries also run standalone.

use std::time::Instant;

/// One timing measurement series.
pub struct BenchStats {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl BenchStats {
    fn quantile(&self, q: f64) -> f64 {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
        }
    }

    /// The median wall-clock sample — the same value `report` prints.
    #[allow(dead_code)]
    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn report(&self) {
        let mean = self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64;
        println!(
            "{:44} median {:>12}  mean {:>12}  p10 {:>12}  p90 {:>12}  ({} samples)",
            self.name,
            fmt_ns(self.quantile(0.5)),
            fmt_ns(mean),
            fmt_ns(self.quantile(0.1)),
            fmt_ns(self.quantile(0.9)),
            self.samples_ns.len()
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` with warmup; chooses the iteration count so one sample takes
/// >= ~1 ms (amortizing timer overhead) and caps total time.
pub fn bench_fn<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos().max(1) as f64;
    let iters_per_sample = ((1_000_000.0 / once_ns).ceil() as usize).clamp(1, 10_000);
    let n_samples = if once_ns > 200_000_000.0 { 5 } else { 30 };

    let mut samples = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
    }
    let stats = BenchStats { name: name.to_string(), samples_ns: samples };
    stats.report();
    stats
}

/// Print a bench-section banner.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Throughput measurement: run `f` once, take the number of work units it
/// reports, and print units/second. Returns the wall-clock seconds so
/// callers can derive speedups across configurations (the Table-II
/// threads sweep).
#[allow(dead_code)]
pub fn bench_throughput<F: FnOnce() -> usize>(name: &str, f: F) -> f64 {
    let t = Instant::now();
    let units = f();
    let secs = t.elapsed().as_secs_f64();
    println!(
        "{:44} {:6} units in {:7.2} s  ->  {:8.2} units/s",
        name,
        units,
        secs,
        units as f64 / secs.max(1e-9)
    );
    secs
}
