//! Bench/regeneration target for **Figures 4 and 5** (best-found cost per
//! iteration; cumulative execution cost): runs the reduced experiment and
//! prints both averaged series.

#[path = "harness.rs"]
mod harness;

use ruya::coordinator::{ExperimentConfig, ExperimentRunner};
use ruya::report;

fn main() {
    harness::section("Fig 4 + Fig 5 regeneration (25 reps, native backend)");
    let runner = ExperimentRunner::native();
    let cfg = ExperimentConfig { reps: 25, seed: 0xC0FFEE, curve_len: 48 };
    let result = runner.run_table2(&cfg).expect("experiment");

    let n = result.jobs.len() as f64;
    let avg = |f: &dyn Fn(&ruya::coordinator::JobComparison) -> &Vec<f64>| {
        let mut acc = vec![0.0; cfg.curve_len];
        for j in &result.jobs {
            for (i, v) in f(j).iter().take(cfg.curve_len).enumerate() {
                acc[i] += v / n;
            }
        }
        acc
    };

    let fig4_cp = avg(&|j| &j.cherrypick.best_curve);
    let fig4_ruya = avg(&|j| &j.ruya.best_curve);
    println!(
        "{}",
        report::render_series(&fig4_cp, &fig4_ruya, "Fig 4: best-found cost per iteration")
    );
    // Paper shape check: CherryPick needs ~2x the iterations to reach the
    // cost level Ruya attains early.
    let ruya_at_12 = fig4_ruya[11];
    let cp_cross = fig4_cp.iter().position(|&c| c <= ruya_at_12).map(|p| p + 1);
    println!(
        "# Ruya's iteration-12 level ({ruya_at_12:.3}) reached by CherryPick at iteration {cp_cross:?} (paper: ~24 vs ~12)"
    );

    let fig5_cp = avg(&|j| &j.cherrypick.cum_curve);
    let fig5_ruya = avg(&|j| &j.ruya.cum_curve);
    println!(
        "{}",
        report::render_series(&fig5_cp, &fig5_ruya, "Fig 5: cumulative normalized execution cost")
    );
    println!(
        "# cumulative advantage at iteration 25: {:.2} (CP) vs {:.2} (Ruya)",
        fig5_cp[24], fig5_ruya[24]
    );
}
