//! Bench/regeneration target for **Figure 3** (single-node memory usage
//! over time for five linearly spaced K-Means samples): prints a compact
//! rendering of the five traces and times the series generator.

#[path = "harness.rs"]
mod harness;

use ruya::profiler::SingleNodeProfiler;
use ruya::util::rng::Pcg64;
use ruya::workload::{evaluation_jobs, Framework};

fn main() {
    harness::section("Fig 3 regeneration: memory traces of 5 profiling runs");
    let profiler = SingleNodeProfiler::default();
    let job = evaluation_jobs()
        .into_iter()
        .find(|j| j.algo.name == "K-Means" && j.scale.name() == "huge" && j.algo.framework == Framework::Spark)
        .unwrap();
    let outcome = profiler.profile(&job, 0xC0FFEE);
    for (k, run) in outcome.runs.iter().enumerate() {
        let series = run.series.as_ref().unwrap();
        // ASCII sparkline: 60 buckets over the run.
        let rows = series.as_rows();
        let maxv = rows.iter().map(|r| r.1).fold(0.0f64, f64::max).max(1e-9);
        let buckets = 60.min(rows.len());
        let mut line = String::new();
        for b in 0..buckets {
            let idx = b * rows.len() / buckets;
            let v = rows[idx].1 / maxv;
            let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#'];
            line.push(glyphs[((v * 7.0).round() as usize).min(7)]);
        }
        println!(
            "run {} ({:6.2} GB sample, {:5.0} s, peak {:5.2} GB) |{line}|",
            k + 1,
            run.sample_gb,
            run.runtime_s,
            run.peak_mem_gb
        );
    }
    println!("\nreadings (sample_gb -> peak_mem_gb):");
    for (x, y) in outcome.readings() {
        println!("  {x:7.3} -> {y:7.3}");
    }

    harness::section("timing: one 1 Hz memory series generation");
    let mut rng = Pcg64::from_seed(7);
    harness::bench_fn("memory_series (165 s run)", || {
        let s = profiler.memory_series(&job, 1.5, 165.0, &mut rng);
        std::hint::black_box(s.stable_peak_gb());
    });
}
