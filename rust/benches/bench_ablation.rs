//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  1. flat-job priority-group size (paper §III-D proposes 10–20% of the
//!     space; the evaluation uses 10 configs ≈ 1/7),
//!  2. the leeway margin on linear requirements,
//!  3. single-phase priority-only vs two-phase search (is the phase-2
//!     fallback actually needed?).
//!
//! Each ablation reruns a Table-II slice with one knob changed and
//! reports mean iterations-to-optimal.

#[path = "harness.rs"]
mod harness;

use ruya::coordinator::{ExperimentConfig, ExperimentRunner};
use ruya::workload::evaluation_jobs;

const REPS: usize = 25;

fn mean_iters(runner: &ExperimentRunner, labels: &[&str]) -> (f64, f64) {
    let cfg = ExperimentConfig { reps: REPS, seed: 0xC0FFEE, curve_len: 10 };
    let mut ruya = 0.0;
    let mut cp = 0.0;
    for label in labels {
        let job = evaluation_jobs().into_iter().find(|j| j.label() == *label).unwrap();
        let cmp = runner.compare_job(&job, &cfg).unwrap();
        ruya += cmp.ruya.iters_to[2] / labels.len() as f64;
        cp += cmp.cherrypick.iters_to[2] / labels.len() as f64;
    }
    (ruya, cp)
}

fn main() {
    let flat_jobs = ["Join Spark huge", "Terasort Hadoop huge", "Page Rank Hadoop bigdata"];
    let linear_jobs = ["K-Means Spark bigdata", "K-Means Spark huge", "Naive Bayes Spark huge"];

    harness::section("ablation 1: flat priority-group size (iterations to optimum)");
    for size in [5usize, 10, 15, 20, 30] {
        let mut runner = ExperimentRunner::native();
        runner.planner.flat_group_size = size;
        let (ruya, cp) = mean_iters(&runner, &flat_jobs);
        println!(
            "group size {size:2} ({:4.1}% of space): ruya {ruya:6.2}  cherrypick {cp:6.2}  quotient {:5.1}%",
            100.0 * size as f64 / 69.0,
            100.0 * ruya / cp
        );
    }
    println!("(paper picks 10 ≈ 14% — small groups risk excluding the optimum,\n large groups approach plain BO)");

    harness::section("ablation 2: linear-requirement leeway");
    for leeway in [0.0, 0.02, 0.05, 0.10, 0.25] {
        let mut runner = ExperimentRunner::native();
        runner.planner.leeway = leeway;
        let (ruya, cp) = mean_iters(&runner, &linear_jobs);
        println!(
            "leeway {:4.0}%: ruya {ruya:6.2}  cherrypick {cp:6.2}  quotient {:5.1}%",
            leeway * 100.0,
            100.0 * ruya / cp
        );
    }
    println!("(too much leeway excludes boundary-optimal configurations)");

    harness::section("ablation 3: extremes-fallback fraction (oversized requirements)");
    for frac in [0.05, 0.12, 0.25] {
        let mut runner = ExperimentRunner::native();
        runner.planner.extremes_fraction = frac;
        let (ruya, cp) = mean_iters(&runner, &["Naive Bayes Spark bigdata"]);
        println!(
            "extremes fraction {:4.0}%: ruya {ruya:6.2}  cherrypick {cp:6.2}  quotient {:5.1}%",
            frac * 100.0,
            100.0 * ruya / cp
        );
    }
}
