//! §Perf bench: the per-iteration decision hot path (GP fit + EI over all
//! candidates + hyperparameter grid), native vs XLA backend, across
//! observation counts — the numbers recorded in EXPERIMENTS.md §Perf.

#[path = "harness.rs"]
mod harness;

use ruya::bayesopt::{backend_by_name, hyperparameter_grid, GpBackend};
use ruya::runtime::XlaRuntime;
use ruya::searchspace::SearchSpace;
use ruya::util::rng::Pcg64;

fn bench_backend(backend: &mut dyn GpBackend, space: &SearchSpace) {
    let d = ruya::searchspace::N_FEATURES;
    let m = space.len();
    let features = space.feature_matrix();
    let grid = hyperparameter_grid();
    let mut rng = Pcg64::from_seed(42);

    for &n in &[4usize, 8, 16, 32, 64] {
        // Synthetic observations over the first n configs.
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            x.extend(space.features(i));
            y.push(1.0 + rng.next_f64());
        }
        let cmask: Vec<bool> = (0..m).map(|i| i >= n).collect();
        let hyp = [0.5, 1.0, 1e-3];

        harness::bench_fn(&format!("{}: decide (n={n:2}, m={m})", backend.name()), || {
            std::hint::black_box(
                backend.decide(&x, &y, n, d, &features, &cmask, m, hyp).unwrap(),
            );
        });
        harness::bench_fn(&format!("{}: nll_grid (n={n:2}, H=32)", backend.name()), || {
            std::hint::black_box(backend.nll_grid(&x, &y, n, d, &grid).unwrap());
        });
    }
}

fn main() {
    let space = SearchSpace::scout();

    harness::section("GP decision hot path — native backend");
    let mut native = backend_by_name("native").unwrap();
    bench_backend(native.as_mut(), &space);

    if XlaRuntime::artifacts_available() {
        harness::section("GP decision hot path — XLA backend (AOT artifacts via PJRT)");
        let mut xla = backend_by_name("xla").unwrap();
        bench_backend(xla.as_mut(), &space);
    } else {
        eprintln!("skipping XLA backend: artifacts not built (run `make artifacts`)");
    }

    harness::section("end-to-end per-iteration decision (nll_grid + decide)");
    let mut native = backend_by_name("native").unwrap();
    let d = ruya::searchspace::N_FEATURES;
    let m = space.len();
    let features = space.feature_matrix();
    let grid = hyperparameter_grid();
    let n = 24;
    let mut rng = Pcg64::from_seed(1);
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..n {
        x.extend(space.features(i));
        y.push(1.0 + rng.next_f64());
    }
    let cmask: Vec<bool> = (0..m).map(|i| i >= n).collect();
    harness::bench_fn("native: full decision (n=24)", || {
        let nll = native.nll_grid(&x, &y, n, d, &grid).unwrap();
        let best = nll
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        std::hint::black_box(
            native.decide(&x, &y, n, d, &features, &cmask, m, grid[best]).unwrap(),
        );
    });
}
