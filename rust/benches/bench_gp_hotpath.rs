//! §Perf bench: the per-iteration decision hot path (GP fit + EI over all
//! candidates + hyperparameter grid), native vs XLA backend, across
//! observation counts — the numbers recorded in EXPERIMENTS.md §Perf —
//! plus the incremental-vs-scratch grid-refit sweep introduced with the
//! rank-1 Cholesky factor cache.
//!
//! `--smoke` (the CI mode) runs tiny sizes only and *asserts* that the
//! incremental factor paths engage (appends/slides/reuses > 0), so the
//! hot path cannot silently regress to scratch-fit behavior.

#[path = "harness.rs"]
mod harness;

use ruya::bayesopt::{backend_by_name, hyperparameter_grid, GpBackend, NativeBackend};
use ruya::runtime::XlaRuntime;
use ruya::searchspace::SearchSpace;
use ruya::util::rng::Pcg64;

fn bench_backend(backend: &mut dyn GpBackend, space: &SearchSpace) {
    let d = ruya::searchspace::N_FEATURES;
    let m = space.len();
    let features = space.feature_matrix();
    let grid = hyperparameter_grid();
    let mut rng = Pcg64::from_seed(42);

    for &n in &[4usize, 8, 16, 32, 64] {
        // Synthetic observations over the first n configs.
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            x.extend(space.features(i));
            y.push(1.0 + rng.next_f64());
        }
        let cmask: Vec<bool> = (0..m).map(|i| i >= n).collect();
        let hyp = [0.5, 1.0, 1e-3];

        harness::bench_fn(&format!("{}: decide (n={n:2}, m={m})", backend.name()), || {
            std::hint::black_box(
                backend.decide(&x, &y, n, d, &features, &cmask, m, hyp).unwrap(),
            );
        });
        harness::bench_fn(&format!("{}: nll_grid (n={n:2}, H=32)", backend.name()), || {
            std::hint::black_box(backend.nll_grid(&x, &y, n, d, &grid).unwrap());
        });
    }
}

/// One BO-search-shaped growth sequence: nll_grid over the 32-point grid
/// at every n in 1..=n_max, exactly the per-iteration call pattern of
/// `run_search`. Returns nothing; the backend's caches do the work.
fn grid_growth(backend: &mut NativeBackend, x: &[f64], y: &[f64], n_max: usize, d: usize) {
    let grid = hyperparameter_grid();
    for n in 1..=n_max {
        std::hint::black_box(backend.nll_grid(&x[..n * d], &y[..n], n, d, &grid).unwrap());
    }
}

/// Incremental-vs-scratch grid refit sweep (the tentpole measurement):
/// a full growth sequence 1..=n, H=32, once with the rank-1 factor cache
/// and once forced to refactorize cold on every step (the pre-refactor
/// behavior). Prints both timings plus the speedup per n.
fn incremental_sweep(space: &SearchSpace, sizes: &[usize]) {
    harness::section("incremental vs scratch grid refit (growth 1..=n, H=32, native)");
    let d = ruya::searchspace::N_FEATURES;
    let mut rng = Pcg64::from_seed(7);
    let n_max = *sizes.iter().max().unwrap();
    let mut x = Vec::with_capacity(n_max * d);
    let mut y = Vec::with_capacity(n_max);
    for i in 0..n_max {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    for &n in sizes {
        let inc = harness::bench_fn(&format!("incremental grid growth (n=1..={n:2})"), || {
            let mut b = NativeBackend::new();
            grid_growth(&mut b, &x, &y, n, d);
        });
        let scr = harness::bench_fn(&format!("scratch     grid growth (n=1..={n:2})"), || {
            let mut b = NativeBackend::new();
            b.set_incremental(false);
            grid_growth(&mut b, &x, &y, n, d);
        });
        println!(
            "    -> speedup at n={n:2}: {:.2}x (incremental {} vs scratch {})",
            scr.median() / inc.median(),
            harness::fmt_ns(inc.median()),
            harness::fmt_ns(scr.median()),
        );
    }
}

/// Worker-pool scaling of the grid nll sweep (the `--gp-threads` axis):
/// the same growth sequence at 1/2/4/8 GP threads. Results are
/// bit-identical for every value (the deterministic-reduction contract;
/// see `assert_parallel_sweep_engages`) — only the latency moves.
fn thread_sweep(space: &SearchSpace, n: usize) {
    harness::section(&format!(
        "grid nll sweep across the GP worker pool (growth 1..={n}, H=32)"
    ));
    let d = ruya::searchspace::N_FEATURES;
    let mut rng = Pcg64::from_seed(11);
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    let mut serial = 0.0;
    for &t in &[1usize, 2, 4, 8] {
        let stats =
            harness::bench_fn(&format!("gp-threads {t}: grid growth (n=1..={n:2})"), || {
                let mut b = NativeBackend::new();
                b.set_parallelism(t);
                grid_growth(&mut b, &x, &y, n, d);
            });
        if t == 1 {
            serial = stats.median();
        } else {
            println!(
                "    -> speedup at {t} gp-threads: {:.2}x",
                serial / stats.median()
            );
        }
    }
}

/// Functional guard (always run; part of the `--smoke` contract): the
/// worker-pool nll sweep must engage at gp-threads 8 and stay
/// bit-identical to the serial sweep over a whole growth sequence.
fn assert_parallel_sweep_engages(space: &SearchSpace) {
    let d = ruya::searchspace::N_FEATURES;
    let grid = hyperparameter_grid();
    let mut rng = Pcg64::from_seed(5);
    let n_max = 10usize;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..n_max {
        x.extend(space.features(i));
        y.push(1.0 + rng.next_f64());
    }
    let mut serial = NativeBackend::new();
    let mut par = NativeBackend::new();
    par.set_parallelism(8);
    for n in 1..=n_max {
        let a = serial.nll_grid(&x[..n * d], &y[..n], n, d, &grid).unwrap();
        let b = par.nll_grid(&x[..n * d], &y[..n], n, d, &grid).unwrap();
        for (g, (va, vb)) in a.iter().zip(&b).enumerate() {
            assert!(
                va.to_bits() == vb.to_bits(),
                "threaded nll[{g}] not bit-identical at n={n}: {va} vs {vb}"
            );
        }
    }
    let s = par.decide_stats();
    assert!(s.parallel_nll_sweeps > 0, "worker-pool nll sweep never engaged: {s:?}");
    assert_eq!(serial.decide_stats().parallel_nll_sweeps, 0, "serial backend took the pool");
    println!("parallel nll-sweep guard: OK ({s:?})");
}

/// Functional guard (always run; the whole point of `--smoke`): drive a
/// growth + sliding-window sequence and assert the incremental paths
/// engaged. A regression to scratch fits fails here, not just in timing.
fn assert_incremental_engages(space: &SearchSpace) {
    let d = ruya::searchspace::N_FEATURES;
    let grid = hyperparameter_grid();
    let mut rng = Pcg64::from_seed(3);
    let total = 12usize;
    let window = 8usize;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..total {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    let mut b = NativeBackend::new();
    let m = space.len();
    let features = space.feature_matrix();
    for step in 3..=total {
        let (lo, n) = if step <= window { (0, step) } else { (step - window, window) };
        let xs = &x[lo * d..(lo + n) * d];
        let ys = &y[lo..lo + n];
        b.nll_grid(xs, ys, n, d, &grid).unwrap();
        // decide right after nll_grid, as the search loop does.
        let cmask: Vec<bool> = (0..m).map(|i| i >= n).collect();
        b.decide(xs, ys, n, d, &features, &cmask, m, grid[5]).unwrap();
    }
    let s = b.factor_stats();
    assert!(s.appends > 0, "rank-1 append path never engaged: {s:?}");
    assert!(s.slides > 0, "sliding-window downdate path never engaged: {s:?}");
    assert!(s.reuses > 0, "decide-after-nll_grid reuse path never engaged: {s:?}");
    assert!(
        s.appends + s.slides > s.cold_fits,
        "incremental path did not dominate cold fits: {s:?}"
    );
    println!("incremental-path guard: OK ({s:?})");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let space = SearchSpace::scout();

    if !smoke {
        harness::section("GP decision hot path — native backend");
        let mut native = backend_by_name("native").unwrap();
        bench_backend(native.as_mut(), &space);

        if XlaRuntime::artifacts_available() {
            harness::section("GP decision hot path — XLA backend (AOT artifacts via PJRT)");
            let mut xla = backend_by_name("xla").unwrap();
            bench_backend(xla.as_mut(), &space);
        } else {
            eprintln!("skipping XLA backend: artifacts not built (run `make artifacts`)");
        }
    }

    let sizes: &[usize] = if smoke { &[8, 16] } else { &[8, 16, 24, 32, 48, 64] };
    incremental_sweep(&space, sizes);
    thread_sweep(&space, if smoke { 16 } else { 48 });
    assert_incremental_engages(&space);
    assert_parallel_sweep_engages(&space);

    if smoke {
        println!("\nsmoke mode: skipping the full decision-path sections");
        return;
    }

    harness::section("end-to-end per-iteration decision (nll_grid + decide)");
    let mut native = backend_by_name("native").unwrap();
    let d = ruya::searchspace::N_FEATURES;
    let m = space.len();
    let features = space.feature_matrix();
    let grid = hyperparameter_grid();
    let n = 24;
    let mut rng = Pcg64::from_seed(1);
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..n {
        x.extend(space.features(i));
        y.push(1.0 + rng.next_f64());
    }
    let cmask: Vec<bool> = (0..m).map(|i| i >= n).collect();
    harness::bench_fn("native: full decision (n=24)", || {
        let nll = native.nll_grid(&x, &y, n, d, &grid).unwrap();
        let best = nll
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        std::hint::black_box(
            native.decide(&x, &y, n, d, &features, &cmask, m, grid[best]).unwrap(),
        );
    });
}
