//! §Perf bench: the per-iteration decision hot path (GP fit + EI over all
//! candidates + hyperparameter grid), native vs XLA backend, across
//! observation counts — the numbers recorded in EXPERIMENTS.md §Perf —
//! plus the incremental-vs-scratch grid-refit sweep introduced with the
//! rank-1 Cholesky factor cache.
//!
//! `--smoke` (the CI mode) runs tiny sizes only and *asserts* that the
//! incremental factor paths engage (appends/slides/reuses > 0), that the
//! persistent worker pool spawns once and is reused across consecutive
//! `nll_grid`+`decide` calls (serial below the work-size floor,
//! bit-identical above it — including over randomized fuzz scripts),
//! that the stage-split low-rank sweep does its `Kuu`/`B` builds once
//! per (lengthscale, variance) group (8 for the 32-slot grid, not 32),
//! that the adaptive `--gp-threads` default engages on multicore
//! hosts, that the SIMD dispatch state matches the environment
//! (vectorized on AVX2+FMA hosts unless `RUYA_FORCE_SCALAR` forces the
//! scalar twins), and that the exact sweep batches each (lengthscale,
//! variance) group's noise levels into one multi-RHS solve — so the hot
//! path cannot silently regress on any axis.
//!
//! The SIMD sections report per-kernel GFLOP/s (dot, squared-distance
//! rows, Matérn Gram build, packed triangular solves) with the
//! vectorized kernels on vs forced scalar, plus the composite
//! single-lane cold-refit cell (n=64, H=32) whose SIMD-vs-scalar ratio
//! is the ISSUE's >=4x target.

#[path = "harness.rs"]
mod harness;

use ruya::bayesopt::chol::{packed_row_start, solve_lower_packed, solve_upper_t_packed};
use ruya::bayesopt::kernel::{dot, matern52_gram_from_d2, pairwise_sqdist};
use ruya::bayesopt::{
    adaptive_gp_threads, backend_by_name, hyperparameter_grid, set_simd, simd_active,
    simd_available, GpBackend, NativeBackend, DECIDE_TILE, GP_POOL_MIN_OBS,
};
use ruya::runtime::XlaRuntime;
use ruya::searchspace::SearchSpace;
use ruya::testkit::{assert_parallel_parity, random_scripts};
use ruya::util::rng::Pcg64;

fn bench_backend(backend: &mut dyn GpBackend, space: &SearchSpace) {
    let d = ruya::searchspace::N_FEATURES;
    let m = space.len();
    let features = space.feature_matrix();
    let grid = hyperparameter_grid();
    let mut rng = Pcg64::from_seed(42);

    for &n in &[4usize, 8, 16, 32, 64] {
        // Synthetic observations over the first n configs.
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            x.extend(space.features(i));
            y.push(1.0 + rng.next_f64());
        }
        let cmask: Vec<bool> = (0..m).map(|i| i >= n).collect();
        let hyp = [0.5, 1.0, 1e-3];

        harness::bench_fn(&format!("{}: decide (n={n:2}, m={m})", backend.name()), || {
            std::hint::black_box(
                backend.decide(&x, &y, n, d, &features, &cmask, m, hyp).unwrap(),
            );
        });
        harness::bench_fn(&format!("{}: nll_grid (n={n:2}, H=32)", backend.name()), || {
            std::hint::black_box(backend.nll_grid(&x, &y, n, d, &grid).unwrap());
        });
    }
}

/// One BO-search-shaped growth sequence: nll_grid over the 32-point grid
/// at every n in 1..=n_max, exactly the per-iteration call pattern of
/// `run_search`. Returns nothing; the backend's caches do the work.
fn grid_growth(backend: &mut NativeBackend, x: &[f64], y: &[f64], n_max: usize, d: usize) {
    let grid = hyperparameter_grid();
    for n in 1..=n_max {
        std::hint::black_box(backend.nll_grid(&x[..n * d], &y[..n], n, d, &grid).unwrap());
    }
}

/// Incremental-vs-scratch grid refit sweep (the tentpole measurement):
/// a full growth sequence 1..=n, H=32, once with the rank-1 factor cache
/// and once forced to refactorize cold on every step (the pre-refactor
/// behavior). Prints both timings plus the speedup per n.
fn incremental_sweep(space: &SearchSpace, sizes: &[usize]) {
    harness::section("incremental vs scratch grid refit (growth 1..=n, H=32, native)");
    let d = ruya::searchspace::N_FEATURES;
    let mut rng = Pcg64::from_seed(7);
    let n_max = *sizes.iter().max().unwrap();
    let mut x = Vec::with_capacity(n_max * d);
    let mut y = Vec::with_capacity(n_max);
    for i in 0..n_max {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    for &n in sizes {
        // Serial on purpose: this cell isolates the algorithmic
        // incremental-vs-scratch effect from the (adaptive-default)
        // pool's scaling, which thread_sweep measures separately.
        let inc = harness::bench_fn(&format!("incremental grid growth (n=1..={n:2})"), || {
            let mut b = NativeBackend::new();
            b.set_parallelism(1);
            grid_growth(&mut b, &x, &y, n, d);
        });
        let scr = harness::bench_fn(&format!("scratch     grid growth (n=1..={n:2})"), || {
            let mut b = NativeBackend::new();
            b.set_parallelism(1);
            b.set_incremental(false);
            grid_growth(&mut b, &x, &y, n, d);
        });
        println!(
            "    -> speedup at n={n:2}: {:.2}x (incremental {} vs scratch {})",
            scr.median() / inc.median(),
            harness::fmt_ns(inc.median()),
            harness::fmt_ns(scr.median()),
        );
    }
}

/// Worker-pool scaling of the grid nll sweep (the `--gp-threads` axis):
/// the same growth sequence at 1/2/4/8 GP threads. Results are
/// bit-identical for every value (the deterministic-reduction contract;
/// see `assert_parallel_sweep_engages`) — only the latency moves.
fn thread_sweep(space: &SearchSpace, n: usize) {
    harness::section(&format!(
        "grid nll sweep across the GP worker pool (growth 1..={n}, H=32)"
    ));
    let d = ruya::searchspace::N_FEATURES;
    let mut rng = Pcg64::from_seed(11);
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    let mut serial = 0.0;
    for &t in &[1usize, 2, 4, 8] {
        let stats =
            harness::bench_fn(&format!("gp-threads {t}: grid growth (n=1..={n:2})"), || {
                let mut b = NativeBackend::new();
                b.set_parallelism(t);
                grid_growth(&mut b, &x, &y, n, d);
            });
        if t == 1 {
            serial = stats.median();
        } else {
            println!(
                "    -> speedup at {t} gp-threads: {:.2}x",
                serial / stats.median()
            );
        }
    }
}

/// Functional guard (always run; part of the `--smoke` contract): the
/// worker-pool nll sweep must engage at gp-threads 8 once the growth
/// clears the serial floor, stay serial below it, and remain
/// bit-identical to the serial sweep over the whole sequence — with the
/// backend attached to the process-global pool exactly once and every
/// later engaging call (nll_grid *and* a multi-tile decide) served as a
/// reuse.
fn assert_parallel_sweep_engages(space: &SearchSpace) {
    let d = ruya::searchspace::N_FEATURES;
    let grid = hyperparameter_grid();
    let mut rng = Pcg64::from_seed(5);
    let n_max = GP_POOL_MIN_OBS + 8; // crosses the serial floor mid-growth
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..n_max {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    // A three-tile candidate set so the decide fan-out engages too.
    let m = DECIDE_TILE * 2 + 17;
    let xc: Vec<f64> = (0..m * d).map(|i| ((i * 31 + 7) % 97) as f64 / 97.0).collect();
    let cmask = vec![true; m];
    let mut serial = NativeBackend::new();
    serial.set_parallelism(1);
    let mut par = NativeBackend::new();
    par.set_parallelism(8);
    for n in 1..=n_max {
        let a = serial.nll_grid(&x[..n * d], &y[..n], n, d, &grid).unwrap();
        let b = par.nll_grid(&x[..n * d], &y[..n], n, d, &grid).unwrap();
        for (g, (va, vb)) in a.iter().zip(&b).enumerate() {
            assert!(
                va.to_bits() == vb.to_bits(),
                "threaded nll[{g}] not bit-identical at n={n}: {va} vs {vb}"
            );
        }
        if n <= GP_POOL_MIN_OBS {
            let s = par.decide_stats();
            assert_eq!(
                s.parallel_nll_sweeps, 0,
                "serial floor breached at n={n}: {s:?}"
            );
        }
        let da = serial.decide(&x[..n * d], &y[..n], n, d, &xc, &cmask, m, grid[5]).unwrap();
        let db = par.decide(&x[..n * d], &y[..n], n, d, &xc, &cmask, m, grid[5]).unwrap();
        for j in [0usize, DECIDE_TILE - 1, DECIDE_TILE, m - 1] {
            assert!(
                da.ei[j].to_bits() == db.ei[j].to_bits(),
                "threaded ei[{j}] not bit-identical at n={n}"
            );
        }
    }
    let s = par.decide_stats();
    assert!(s.parallel_nll_sweeps > 0, "worker-pool nll sweep never engaged: {s:?}");
    assert!(s.parallel_decide_fanouts > 0, "decide tile fan-out never engaged: {s:?}");
    assert!(s.serial_floor_bypasses > 0, "serial floor never applied: {s:?}");
    // The pool is process-global now: whether *this* backend's attach
    // spawned it depends on what ran earlier in the bench process, so
    // the attach is pinned exactly and the spawn only bounded.
    assert_eq!(s.global_pool_attach, 1, "never attached to the shared pool: {s:?}");
    assert!(s.pool_creates <= 1, "pool spawned more than once: {s:?}");
    assert!(
        s.pool_reuses >= s.parallel_nll_sweeps + s.parallel_decide_fanouts - 1,
        "pool not reused across consecutive nll_grid+decide calls: {s:?}"
    );
    assert_eq!(serial.decide_stats().parallel_nll_sweeps, 0, "serial backend took the pool");
    println!("parallel nll-sweep + persistent-pool guard: OK ({s:?})");
}

/// Functional guard (always run in `--smoke`): the stage-split low-rank
/// `nll_grid` must do its `Kuu`/`B` builds once per (lengthscale,
/// variance) group — 8 builds for the 32-slot grid, not 32 — with one
/// noise stage per slot, and the inducing refresh must go incremental on
/// the appended follow-up sweep.
fn assert_stage_split_engages(space: &SearchSpace) {
    let d = ruya::searchspace::N_FEATURES;
    let grid = hyperparameter_grid();
    assert_eq!(grid.len(), 32, "the guard assumes the 32-slot grid");
    let mut rng = Pcg64::from_seed(9);
    let n = 24;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..=n {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    let mut b = NativeBackend::new();
    b.set_lowrank_nll_threshold(16); // route these sweeps low-rank
    b.nll_grid(&x[..n * d], &y[..n], n, d, &grid).unwrap();
    let s = b.decide_stats();
    assert_eq!(s.nll_lowrank, 1, "sweep not routed low-rank: {s:?}");
    assert_eq!(
        s.lowrank_hyp_stage_builds, 8,
        "stage split must build Kuu/B once per (ls, var) group (8 for the 32-slot grid): {s:?}"
    );
    assert_eq!(s.lowrank_noise_stage_builds, 32, "one noise stage per slot: {s:?}");
    assert_eq!(s.fps_full_refreshes, 1, "first sweep selects inducing in full: {s:?}");
    // One appended observation: the refresh must stay incremental.
    b.nll_grid(&x, &y, n + 1, d, &grid).unwrap();
    let s = b.decide_stats();
    assert_eq!(s.fps_full_refreshes, 1, "append re-ran full FPS: {s:?}");
    assert_eq!(s.fps_incremental_refreshes, 1, "append not served incrementally: {s:?}");
    assert_eq!(s.lowrank_hyp_stage_builds, 16, "second sweep re-uses the grouping: {s:?}");
    println!("stage-split + incremental-inducing guard: OK ({s:?})");
}

/// Functional guard (always run in `--smoke`, and CI's dedicated
/// `--default-threads-smoke` step): without `--gp-threads` anywhere the
/// adaptive default must engage the pool on multicore hosts — and the
/// serial floor must keep n <= GP_POOL_MIN_OBS sweeps poolless even
/// then.
fn assert_adaptive_default_and_floor(space: &SearchSpace) {
    let d = ruya::searchspace::N_FEATURES;
    let grid = hyperparameter_grid();
    let mut rng = Pcg64::from_seed(13);
    let n_big = GP_POOL_MIN_OBS + 8;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..n_big {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    let mut b = NativeBackend::new(); // no set_parallelism: the adaptive default
    assert_eq!(b.parallelism(), adaptive_gp_threads());
    // Below the floor: poolless, whatever the adaptive width.
    let n_small = GP_POOL_MIN_OBS.min(n_big);
    b.nll_grid(&x[..n_small * d], &y[..n_small], n_small, d, &grid).unwrap();
    let s = b.decide_stats();
    assert_eq!(s.global_pool_attach, 0, "n <= {GP_POOL_MIN_OBS} must stay poolless: {s:?}");
    assert_eq!(s.parallel_nll_sweeps, 0, "floored sweep went parallel: {s:?}");
    // Past the floor: the adaptive default engages (on multicore hosts)
    // by attaching to the process-global pool, which (absent a
    // configure_global_pool_width call — none in this bench) was
    // spawned at the adaptive width regardless of which backend in the
    // process got there first.
    b.nll_grid(&x, &y, n_big, d, &grid).unwrap();
    let s = b.decide_stats();
    if adaptive_gp_threads() > 1 {
        assert!(s.parallel_nll_sweeps > 0, "adaptive default never engaged: {s:?}");
        assert_eq!(s.global_pool_attach, 1, "adaptive backend never attached: {s:?}");
        assert_eq!(
            s.pool_thread_count,
            adaptive_gp_threads() as u64,
            "shared pool not at the adaptive width: {s:?}"
        );
        println!("adaptive-default guard: OK at {} lanes ({s:?})", adaptive_gp_threads());
    } else {
        println!("adaptive-default guard: single-core host, pool stays serial (OK)");
    }
}

/// Functional guard (always run in `--smoke`): randomized-script fuzz —
/// serial vs pooled must be bit-identical at 1/2/4/8 threads (the
/// reference lane inside the harness is the 1 case) over generated
/// append/slide/replace programs. The full 32-script corpus runs in
/// `tests/fuzz_parity.rs`; this is the bench-smoke slice of it.
fn assert_fuzz_parity_smoke() {
    let grid = hyperparameter_grid();
    for (i, script) in random_scripts(0xB1_5EED, 3).iter().enumerate() {
        let dd = script.dim();
        let m = 8;
        let xc: Vec<f64> =
            (0..m * dd).map(|j| ((j * 29 + i * 13 + 7) % 97) as f64 / 97.0).collect();
        let make = || {
            let mut b = NativeBackend::new();
            b.set_pool_min_obs(0);
            b
        };
        assert_parallel_parity(&make, &[2, 4, 8], script, &xc, m, &grid);
    }
    println!("randomized-script parity fuzz (bench smoke): OK");
}

/// Functional guard (always run; the whole point of `--smoke`): drive a
/// growth + sliding-window sequence and assert the incremental paths
/// engaged. A regression to scratch fits fails here, not just in timing.
fn assert_incremental_engages(space: &SearchSpace) {
    let d = ruya::searchspace::N_FEATURES;
    let grid = hyperparameter_grid();
    let mut rng = Pcg64::from_seed(3);
    let total = 12usize;
    let window = 8usize;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..total {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    let mut b = NativeBackend::new();
    let m = space.len();
    let features = space.feature_matrix();
    for step in 3..=total {
        let (lo, n) = if step <= window { (0, step) } else { (step - window, window) };
        let xs = &x[lo * d..(lo + n) * d];
        let ys = &y[lo..lo + n];
        b.nll_grid(xs, ys, n, d, &grid).unwrap();
        // decide right after nll_grid, as the search loop does.
        let cmask: Vec<bool> = (0..m).map(|i| i >= n).collect();
        b.decide(xs, ys, n, d, &features, &cmask, m, grid[5]).unwrap();
    }
    let s = b.factor_stats();
    assert!(s.appends > 0, "rank-1 append path never engaged: {s:?}");
    assert!(s.slides > 0, "sliding-window downdate path never engaged: {s:?}");
    assert!(s.reuses > 0, "decide-after-nll_grid reuse path never engaged: {s:?}");
    assert!(
        s.appends + s.slides > s.cold_fits,
        "incremental path did not dominate cold fits: {s:?}"
    );
    println!("incremental-path guard: OK ({s:?})");
}

/// Restores the process-global SIMD dispatch mode on scope exit so a
/// panicking section can't leave the rest of the bench toggled.
struct SimdModeGuard(bool);
impl Drop for SimdModeGuard {
    fn drop(&mut self) {
        set_simd(self.0);
    }
}

/// Nominal flops over median nanoseconds is exactly GFLOP/s.
fn gflops(flops: f64, median_ns: f64) -> f64 {
    flops / median_ns
}

/// Per-kernel throughput: each vectorized micro-kernel timed with the
/// scalar twins forced, then (on AVX2+FMA hosts) with SIMD dispatch on,
/// reported as GFLOP/s plus the per-kernel SIMD-vs-scalar ratio. Flop
/// counts are nominal — `exp`/`sqrt` count as one op each, so the Gram
/// cell understates the real work — but both modes share the count, so
/// the ratios are exact.
fn simd_kernel_section() {
    harness::section("SIMD micro-kernels: GFLOP/s, vectorized vs forced scalar");
    let n = 256usize;
    let d = 8usize;
    let len = 4096usize;
    let a: Vec<f64> = (0..len).map(|i| ((i * 37 + 11) % 101) as f64 / 101.0).collect();
    let b: Vec<f64> = (0..len).map(|i| ((i * 53 + 29) % 103) as f64 / 103.0).collect();
    let x: Vec<f64> = (0..n * d).map(|i| ((i * 31 + 7) % 97) as f64 / 97.0).collect();
    let mut d2 = Vec::new();
    pairwise_sqdist(&x, n, d, &mut d2);
    // A well-conditioned packed lower factor (unit diagonal, small
    // off-diagonals): the triangular solves only read the factor, so
    // no Cholesky is needed to time them.
    let mut l = vec![0.0; packed_row_start(n)];
    for i in 0..n {
        let s = packed_row_start(i);
        for j in 0..i {
            l[s + j] = 1e-3 / (1.0 + (i - j) as f64);
        }
        l[s + i] = 1.0;
    }
    let rhs = vec![1.0; n];

    // (median ns, nominal flops) per kernel under the current mode.
    let measure = |label: &str| -> Vec<(f64, f64)> {
        let mut buf = Vec::new();
        let mut v = vec![0.0; n];
        let mut out = Vec::new();
        let s = harness::bench_fn(&format!("{label}: dot (len={len})"), || {
            std::hint::black_box(dot(&a, &b));
        });
        out.push((s.median(), 2.0 * len as f64));
        let s = harness::bench_fn(&format!("{label}: pairwise_sqdist (n={n}, d={d})"), || {
            pairwise_sqdist(&x, n, d, &mut buf);
            std::hint::black_box(buf[n * n - 1]);
        });
        out.push((s.median(), 3.0 * d as f64 * (n * (n - 1) / 2) as f64));
        let s = harness::bench_fn(&format!("{label}: matern52 gram (n={n})"), || {
            matern52_gram_from_d2(&d2, n, 0.5, 1.0, &mut buf);
            std::hint::black_box(buf[n * n - 1]);
        });
        out.push((s.median(), 10.0 * (n * (n + 1) / 2) as f64));
        let s = harness::bench_fn(&format!("{label}: packed fwd+bwd solve (n={n})"), || {
            v.copy_from_slice(&rhs);
            solve_lower_packed(&l, n, &mut v);
            solve_upper_t_packed(&l, n, &mut v);
            std::hint::black_box(v[n - 1]);
        });
        out.push((s.median(), 4.0 * (n * n / 2) as f64));
        out
    };

    let _restore = SimdModeGuard(simd_active());
    set_simd(false);
    let scalar = measure("scalar");
    let names = ["dot", "pairwise_sqdist", "matern52 gram", "packed solves"];
    if simd_available() {
        set_simd(true);
        let simd = measure("simd  ");
        for ((name, (sc_ns, flops)), (si_ns, _)) in names.iter().zip(&scalar).zip(&simd) {
            println!(
                "    -> {name:16} scalar {:6.2} GFLOP/s   simd {:6.2} GFLOP/s   ratio {:.2}x",
                gflops(*flops, *sc_ns),
                gflops(*flops, *si_ns),
                sc_ns / si_ns,
            );
        }
    } else {
        for (name, (sc_ns, flops)) in names.iter().zip(&scalar) {
            println!(
                "    -> {name:16} scalar {:6.2} GFLOP/s (host lacks AVX2+FMA; no simd lane)",
                gflops(*flops, *sc_ns)
            );
        }
    }
}

/// The composite acceptance cell: a single-lane (`--gp-threads 1`) cold
/// grid refit at n=64 over the 32-slot grid — every slot refactorized
/// from scratch, the pre-SIMD hot loop — timed with the vectorized
/// kernels on vs forced scalar. The printed ratio is the regression-
/// checkable ISSUE target (>= 4x on AVX2+FMA hosts).
fn simd_composite_ratio(space: &SearchSpace) {
    harness::section("single-lane cold grid refit (n=64, H=32): simd vs scalar");
    let d = ruya::searchspace::N_FEATURES;
    let grid = hyperparameter_grid();
    let mut rng = Pcg64::from_seed(17);
    let n = 64usize;
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    let cell = |label: &str| -> f64 {
        harness::bench_fn(&format!("{label}: cold nll_grid (n=64, H=32, 1 lane)"), || {
            let mut b = NativeBackend::new();
            b.set_parallelism(1);
            b.set_incremental(false);
            std::hint::black_box(b.nll_grid(&x, &y, n, d, &grid).unwrap());
        })
        .median()
    };
    let _restore = SimdModeGuard(simd_active());
    set_simd(false);
    let scalar = cell("scalar");
    if simd_available() {
        set_simd(true);
        let simd = cell("simd  ");
        println!(
            "    -> simd-vs-scalar single-lane ratio: {:.2}x (target >= 4x; simd {} vs scalar {})",
            scalar / simd,
            harness::fmt_ns(simd),
            harness::fmt_ns(scalar),
        );
    } else {
        println!("    -> host lacks AVX2+FMA: no vectorized lane to compare");
    }
}

/// Functional guard (always run in `--smoke`): the SIMD dispatch state
/// must match the environment — vectorized on AVX2+FMA hosts unless
/// `RUYA_FORCE_SCALAR` forces the scalar twins — and the exact nll
/// sweep must batch each (lengthscale, variance) group's noise levels
/// into one interleaved multi-RHS solve (8 batches of 4 for the
/// 32-slot grid).
fn assert_simd_dispatch_and_multi_rhs(space: &SearchSpace) {
    let forced_scalar = std::env::var("RUYA_FORCE_SCALAR")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let expect = simd_available() && !forced_scalar;
    assert_eq!(
        simd_active(),
        expect,
        "simd dispatch does not match the environment \
         (avx2+fma available={}, RUYA_FORCE_SCALAR set={forced_scalar})",
        simd_available(),
    );
    let d = ruya::searchspace::N_FEATURES;
    let grid = hyperparameter_grid();
    assert_eq!(grid.len(), 32, "the guard assumes the 32-slot grid");
    let mut rng = Pcg64::from_seed(21);
    let n = 12usize;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..n {
        x.extend(space.features(i % space.len()));
        y.push(1.0 + rng.next_f64());
    }
    let mut b = NativeBackend::new();
    b.set_parallelism(1);
    b.nll_grid(&x, &y, n, d, &grid).unwrap();
    let s = b.decide_stats();
    assert_eq!(
        s.multi_rhs_noise_solves, 8,
        "exact sweep must batch the 4 noise levels of each of the 8 \
         (ls, var) groups into one multi-RHS solve: {s:?}"
    );
    println!(
        "simd-dispatch + multi-RHS guard: OK (simd_active={}, {} batched groups)",
        simd_active(),
        s.multi_rhs_noise_solves
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // CI's dedicated default-threads step: only the adaptive-default /
    // serial-floor guard (no --gp-threads anywhere in it), fast enough
    // to run on every push in both debug and release.
    if std::env::args().any(|a| a == "--default-threads-smoke") {
        assert_adaptive_default_and_floor(&SearchSpace::scout());
        return;
    }
    let space = SearchSpace::scout();

    if !smoke {
        harness::section("GP decision hot path — native backend");
        let mut native = backend_by_name("native").unwrap();
        bench_backend(native.as_mut(), &space);

        if XlaRuntime::artifacts_available() {
            harness::section("GP decision hot path — XLA backend (AOT artifacts via PJRT)");
            let mut xla = backend_by_name("xla").unwrap();
            bench_backend(xla.as_mut(), &space);
        } else {
            eprintln!("skipping XLA backend: artifacts not built (run `make artifacts`)");
        }
    }

    let sizes: &[usize] = if smoke { &[8, 16] } else { &[8, 16, 24, 32, 48, 64] };
    incremental_sweep(&space, sizes);
    // 24 > GP_POOL_MIN_OBS even in smoke mode, so the pool axis is real.
    thread_sweep(&space, if smoke { 24 } else { 48 });
    assert_incremental_engages(&space);
    assert_parallel_sweep_engages(&space);
    assert_stage_split_engages(&space);
    assert_adaptive_default_and_floor(&space);
    assert_fuzz_parity_smoke();
    assert_simd_dispatch_and_multi_rhs(&space);

    simd_kernel_section();
    simd_composite_ratio(&space);

    if smoke {
        println!("\nsmoke mode: skipping the full decision-path sections");
        return;
    }

    harness::section("end-to-end per-iteration decision (nll_grid + decide)");
    let mut native = backend_by_name("native").unwrap();
    let d = ruya::searchspace::N_FEATURES;
    let m = space.len();
    let features = space.feature_matrix();
    let grid = hyperparameter_grid();
    let n = 24;
    let mut rng = Pcg64::from_seed(1);
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..n {
        x.extend(space.features(i));
        y.push(1.0 + rng.next_f64());
    }
    let cmask: Vec<bool> = (0..m).map(|i| i >= n).collect();
    harness::bench_fn("native: full decision (n=24)", || {
        let nll = native.nll_grid(&x, &y, n, d, &grid).unwrap();
        let best = nll
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        std::hint::black_box(
            native.decide(&x, &y, n, d, &features, &cmask, m, grid[best]).unwrap(),
        );
    });
}
