//! Bench/regeneration target for **Figure 1** (total cluster RAM vs
//! normalized cost for K-Means on Spark): prints the per-machine-type
//! cost series and verifies the memory cliff is present.

#[path = "harness.rs"]
mod harness;

use ruya::searchspace::SearchSpace;
use ruya::workload::{evaluation_jobs, ClusterSim, Framework, JobCostTable};

fn main() {
    harness::section("Fig 1 regeneration: RAM vs cost, K-Means on Spark");
    let space = SearchSpace::scout();
    let sim = ClusterSim::default();
    for scale in ["huge", "bigdata"] {
        let job = evaluation_jobs()
            .into_iter()
            .find(|j| {
                j.algo.name == "K-Means"
                    && j.scale.name() == scale
                    && j.algo.framework == Framework::Spark
            })
            .unwrap();
        let table = JobCostTable::build(&sim, &job, &space);
        println!("\n# K-Means Spark {scale} (cache need {:.0} GB)", job.true_cache_need_gb());
        println!("{:>9}  {:>9}  {:>7}  machine", "ram_gb", "cost", "cached");
        let mut rows: Vec<usize> = (0..space.len()).collect();
        rows.sort_by(|&a, &b| {
            space.config(a).total_memory_gb().partial_cmp(&space.config(b).total_memory_gb()).unwrap()
        });
        for i in rows {
            let c = space.config(i);
            let fit = sim.cache_fit(&job, &c);
            println!(
                "{:9.1}  {:9.3}  {:7.2}  {} x{}",
                c.total_memory_gb(),
                table.normalized[i],
                fit,
                c.machine_type().name,
                c.nodes
            );
        }

        // Cliff summary: mean normalized cost below vs above the cliff.
        let (mut below, mut above) = (Vec::new(), Vec::new());
        for i in 0..space.len() {
            let fit = sim.cache_fit(&job, &space.config(i));
            if fit < 1.0 { below.push(table.normalized[i]) } else { above.push(table.normalized[i]) }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        println!(
            "# cliff: {} configs below (mean cost {:.2}), {} above (mean cost {:.2})",
            below.len(),
            mean(&below),
            above.len(),
            mean(&above)
        );
    }

    harness::section("timing: full 69-config cost-table build");
    let job = evaluation_jobs().into_iter().find(|j| j.label() == "K-Means Spark bigdata").unwrap();
    harness::bench_fn("JobCostTable::build (69 configs)", || {
        std::hint::black_box(JobCostTable::build(&sim, &job, &space));
    });
}
