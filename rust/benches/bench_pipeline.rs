//! §Perf bench: the end-to-end memory-aware pipeline at catalog scale —
//! profiler → memory model → shortlist → BO inside the shortlist, vs the
//! full-catalog baseline at the same seed and iteration budget.
//!
//! The ablation sweeps generated catalogs of 1k / 10k / 40k configs
//! (the generated grid caps at 42336, so the paper-style "50k" tier runs
//! at 40k) and reports, per memory category: shortlist size, wall-clock
//! per pipeline run, and iterations-to-(cost ≤ 1.1) narrowed vs full.
//!
//! `--smoke` (the CI mode) runs a generated:1000 catalog and *asserts*
//! the narrowing behaves as the paper requires: the shortlist engages
//! and is strictly smaller than the catalog for linear- and flat-memory
//! jobs, degrades to the full catalog for unclear jobs, every narrowed
//! pick stays inside the shortlist, and for the linear-memory Table II
//! jobs the narrowed search reaches a ≤ 1.1-cost configuration in fewer
//! iterations than the full-catalog search at the same seed.

#[path = "harness.rs"]
mod harness;

use ruya::coordinator::{ExperimentRunner, MemoryPipeline, SessionEngine, THRESHOLDS};
use ruya::memmodel::MemCategory;
use ruya::searchspace::SearchSpace;
use ruya::workload::{evaluation_jobs, JobInstance};
use std::time::Instant;

const SEED: u64 = 0xC0FFEE;
const BUDGET: usize = 96;

fn pipeline_over(catalog: usize) -> MemoryPipeline {
    MemoryPipeline::new(
        ExperimentRunner::native().with_space(SearchSpace::generated(SEED, catalog)),
    )
}

fn jobs_by_category() -> Vec<JobInstance> {
    // One representative per memory category (Table I labels).
    ["K-Means Spark huge", "Terasort Hadoop bigdata", "Lin. Regr. Spark huge"]
        .iter()
        .map(|l| evaluation_jobs().into_iter().find(|j| j.label() == *l).expect("known job"))
        .collect()
}

fn fmt_iters(it: Option<usize>) -> String {
    it.map_or_else(|| "-".to_string(), |k| k.to_string())
}

fn sweep(catalog: usize) {
    let pipeline = pipeline_over(catalog);
    let mut engine = SessionEngine::new(0);
    for job in jobs_by_category() {
        let t0 = Instant::now();
        let out = pipeline.run_job(&mut engine, &job, SEED, BUDGET).expect("pipeline run");
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "{:7} configs  {:27} {:7}  shortlist {:>5}/{:<5}  narrow<=1.1 {:>4}  \
             full<=1.1 {:>4}  best {:.4} vs {:.4}  {:6.2}s",
            catalog,
            out.label,
            out.category.name(),
            out.shortlist_len,
            out.catalog_len,
            fmt_iters(out.narrowed_iters_to(THRESHOLDS[1])),
            fmt_iters(out.full_iters_to(THRESHOLDS[1])),
            out.narrowed.best_after(BUDGET),
            out.full.best_after(BUDGET),
            secs
        );
    }
}

fn smoke() {
    harness::section("pipeline smoke (CI guard, generated:1000)");
    let pipeline = pipeline_over(1000);
    let catalog = pipeline.runner.space.len();
    assert_eq!(catalog, 1000, "generated:1000 must produce exactly 1000 configs");

    let mut engine = SessionEngine::new(0);
    let mut linear_narrowed = Vec::new();
    let mut linear_full = Vec::new();
    let t0 = Instant::now();
    for job in evaluation_jobs() {
        let (_, shortlist, _) = pipeline.shortlist_job(&job, SEED);
        match shortlist.category {
            MemCategory::Linear | MemCategory::Flat => {
                assert!(
                    shortlist.engaged(),
                    "{}: {} shortlist did not engage ({} of {} configs)",
                    job.label(),
                    shortlist.category.name(),
                    shortlist.indices.len(),
                    catalog
                );
            }
            MemCategory::Unclear => {
                assert_eq!(
                    shortlist.indices.len(),
                    catalog,
                    "{}: unclear jobs must keep the full space",
                    job.label()
                );
            }
        }

        if shortlist.category != MemCategory::Linear {
            continue;
        }
        // Linear jobs additionally run the narrowed-vs-full comparison,
        // racing the two searches at the identical seed and averaging the
        // verdict over two seeds so one lucky full-catalog trajectory
        // cannot flip it.
        for &seed in &[SEED, SEED ^ 0xBADC0DE] {
            let out = pipeline.run_job(&mut engine, &job, seed, BUDGET).expect("pipeline run");
            for &i in &out.narrowed.tried {
                assert!(
                    shortlist.indices.binary_search(&i).is_ok(),
                    "{}: narrowed pick {i} escaped the shortlist",
                    job.label()
                );
            }
            let narrowed = out.narrowed_iters_to(THRESHOLDS[1]);
            let full = out.full_iters_to(THRESHOLDS[1]);
            println!(
                "  {:27} seed {seed:>9x}  shortlist {:>4}/{catalog}  narrow<=1.1 {:>4}  \
                 full<=1.1 {:>4}",
                out.label,
                out.shortlist_len,
                fmt_iters(narrowed),
                fmt_iters(full)
            );
            linear_narrowed.push(narrowed);
            linear_full.push(full);
        }
    }
    assert_eq!(linear_narrowed.len(), 12, "expected the 6 linear Table II jobs x 2 seeds");

    // The paper's claim, at the smoke scale: narrowing makes the linear
    // jobs reach near-optimal configurations sooner. Not-reached counts
    // as budget+1 executions.
    let spend = |it: &Option<usize>| it.unwrap_or(BUDGET + 1);
    let narrowed_total: usize = linear_narrowed.iter().map(spend).sum();
    let full_total: usize = linear_full.iter().map(spend).sum();
    assert!(
        narrowed_total < full_total,
        "narrowed searches did not beat full-catalog searches over the linear jobs: \
         {narrowed_total} vs {full_total} total executions to cost <= 1.1"
    );
    let strict_win = linear_narrowed.iter().zip(&linear_full).any(|(n, f)| match (n, f) {
        (Some(n), Some(f)) => n < f,
        (Some(_), None) => true,
        _ => false,
    });
    assert!(
        strict_win,
        "no linear job reached cost <= 1.1 in strictly fewer narrowed iterations \
         (narrowed {linear_narrowed:?} vs full {linear_full:?})"
    );

    println!(
        "smoke ok: shortlists engage (linear+flat strict subsets, unclear = catalog), \
         narrowed beats full over the 6 linear jobs x 2 seeds ({narrowed_total} vs \
         {full_total} executions to <=1.1) in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }

    harness::section(&format!(
        "pipeline ablation: narrowed vs full catalog at {BUDGET} iterations each"
    ));
    for &catalog in &[1_000usize, 10_000, 40_000] {
        sweep(catalog);
    }
}
