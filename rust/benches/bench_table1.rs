//! Bench/regeneration target for **Table I** (determined job memory
//! requirement): runs the full profiling + categorization pipeline for
//! all 16 jobs, prints the table, and times the per-job pipeline.

#[path = "harness.rs"]
mod harness;

use ruya::coordinator::ExperimentRunner;
use ruya::memmodel::MemoryModel;
use ruya::profiler::SingleNodeProfiler;
use ruya::report;
use ruya::workload::evaluation_jobs;

fn main() {
    harness::section("Table I regeneration (profile -> categorize -> extrapolate)");
    let runner = ExperimentRunner::native();
    let summaries = runner.profile_all(0xC0FFEE);
    println!("{}", report::render_table1(&summaries));

    harness::section("timing: one full profiling + model fit per job");
    let profiler = SingleNodeProfiler::default();
    for job in evaluation_jobs().iter().take(4) {
        let label = job.label();
        harness::bench_fn(&format!("profile+fit [{label}]"), || {
            let outcome = profiler.profile(job, 0xC0FFEE);
            let model = MemoryModel::fit(&outcome.readings());
            std::hint::black_box(model.r2);
        });
    }
}
