//! Quickstart: the complete Ruya workflow for one recurring job.
//!
//! 1. Profile the job on a (simulated) single machine with five dataset
//!    samples, monitoring memory.
//! 2. Fit the memory model, categorize (linear / flat / unclear) and
//!    extrapolate the cluster memory requirement.
//! 3. Split the 69-configuration search space into a memory-compatible
//!    priority group and the remainder.
//! 4. Run the Bayesian-optimized iterative search, executing candidate
//!    configurations on the (simulated) cluster until the search
//!    converges.
//!
//! Run: `cargo run --release --example quickstart [-- --backend xla]`

use ruya::bayesopt::backend_factory_by_name;
use ruya::coordinator::{ExperimentRunner, SearchPlan};
use ruya::util::cli::Args;
use ruya::workload::{evaluation_jobs, JobCostTable};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let backend_name = args.opt_or("backend", "native");
    let runner = ExperimentRunner::new(backend_factory_by_name(&backend_name)?);

    // The recurring job we need a cluster for: K-Means over ~100 GB.
    let job = evaluation_jobs()
        .into_iter()
        .find(|j| j.label() == "K-Means Spark huge")
        .unwrap();
    println!("job: {} ({} GB input)\n", job.label(), job.input_gb);

    // --- Step 1+2: profile on one machine, model memory use ------------
    let profile = runner.profile_job(&job, 1);
    println!("profiling finished in {:.0} s (simulated laptop)", profile.profiling_time_s);
    println!("memory model: {} (R^2 = {:.3})", profile.table1_cell, profile.model.r2);

    // --- Step 3: split the search space ---------------------------------
    let plan = runner.planner.plan(&profile.model, job.input_gb, &runner.space);
    println!(
        "\nsearch plan: category {}, priority group {}/{} configurations",
        plan.category.name(),
        plan.phases[0].len(),
        runner.space.len()
    );
    for &i in plan.phases[0].iter().take(8) {
        let c = runner.space.config(i);
        println!("  priority: {:16} ({:.0} GB usable)", c.name(), c.usable_memory_gb());
    }

    // --- Step 4: Bayesian-optimized iterative search --------------------
    let table = JobCostTable::build(&runner.sim, &job, &runner.space);
    let outcome = runner.run_one(&table, &plan, 7)?;
    println!("\nsearch trace (backend: {backend_name}):");
    let mut best = f64::INFINITY;
    for (t, (&idx, &cost)) in outcome.tried.iter().zip(&outcome.costs).enumerate() {
        best = best.min(cost);
        println!(
            "  iter {:2}: {:16} cost {:5.2} (best {:5.2}){}",
            t + 1,
            runner.space.config(idx).name(),
            cost,
            best,
            if cost <= 1.0 + 1e-9 { "  <- optimal" } else { "" }
        );
        if cost <= 1.0 + 1e-9 {
            break;
        }
    }
    let found = outcome.first_within(1.0 + 1e-9).unwrap();
    println!("\noptimal configuration found after {found} cluster executions");

    // Compare with the memory-oblivious baseline under the same seed.
    let cp = runner.run_one(&table, &SearchPlan::unpartitioned(&runner.space), 7)?;
    println!(
        "CherryPick baseline (same seed): {} executions",
        cp.first_within(1.0 + 1e-9).unwrap()
    );
    Ok(())
}
