//! Profiling deep-dive (Figure 3): runs the single-node profiling phase
//! for one job of each memory category and renders the memory traces,
//! the fitted model, and the resulting search-space split.
//!
//! Run: `cargo run --release --example profiling_demo`

use ruya::coordinator::RuyaPlanner;
use ruya::memmodel::MemoryModel;
use ruya::profiler::SingleNodeProfiler;
use ruya::searchspace::SearchSpace;
use ruya::workload::evaluation_jobs;

fn sparkline(values: &[(f64, f64)], width: usize) -> String {
    let maxv = values.iter().map(|v| v.1).fold(0.0f64, f64::max).max(1e-9);
    let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#'];
    (0..width)
        .map(|b| {
            let idx = b * values.len() / width;
            let v = values[idx].1 / maxv;
            glyphs[((v * 7.0).round() as usize).min(7)]
        })
        .collect()
}

fn main() {
    let profiler = SingleNodeProfiler::default();
    let planner = RuyaPlanner::default();
    let space = SearchSpace::scout();

    for label in ["K-Means Spark huge", "Terasort Hadoop bigdata", "Log. Regr. Spark huge"] {
        let job = evaluation_jobs().into_iter().find(|j| j.label() == label).unwrap();
        println!("==========================================================");
        println!("job: {} ({} GB input)", job.label(), job.input_gb);
        let outcome = profiler.profile(&job, 0xC0FFEE);
        println!(
            "calibration: {} run(s); total profiling time {:.0} s",
            outcome.calibration.len(),
            outcome.total_s
        );
        println!("\nmemory over time (Fig 3 style, one row per sample size):");
        for (k, run) in outcome.runs.iter().enumerate() {
            let series = run.series.as_ref().unwrap();
            println!(
                "  {:4.2} GB |{}| peak {:.2} GB",
                run.sample_gb,
                sparkline(&series.as_rows(), 56),
                run.peak_mem_gb
            );
            let _ = k;
        }

        let model = MemoryModel::fit(&outcome.readings());
        println!(
            "\nmodel: category {} | slope {:.2} GB/GB | R^2 {:.3}",
            model.category.name(),
            model.slope_gb_per_gb,
            model.r2
        );
        println!("Table I cell: {}", model.table1_cell(job.input_gb));

        let plan = planner.plan(&model, job.input_gb, &space);
        println!(
            "search-space split: {} phase(s), priority {}/{} ({:.0}% of space)\n",
            plan.phases.len(),
            plan.phases[0].len(),
            space.len(),
            plan.priority_fraction * 100.0
        );
    }
}
