//! **End-to-end driver**: the paper's complete evaluation on the real
//! (simulated-substrate) workload, exercising all three layers:
//!
//!   Layer 1/2 — the AOT-compiled Pallas + JAX GP artifacts, executed
//!   through PJRT by the rust runtime on every search iteration (pass
//!   `--backend xla`, the default here when artifacts exist);
//!   Layer 3 — profiling, memory modeling, search-space splitting, the
//!   phased Bayesian search and the full Table II / Fig 4 / Fig 5
//!   bookkeeping.
//!
//! Produces the paper-vs-measured comparison recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example full_reproduction -- \
//!        [--reps N] [--backend native|xla] [--threads N] [--out results/]`
//! Default reps: 200 with the native backend, 20 with the XLA backend
//! (one PJRT call per iteration; same math, f32).

use ruya::bayesopt::backend_factory_by_name;
use ruya::coordinator::{ExperimentConfig, ExperimentRunner};
use ruya::report;
use ruya::runtime::XlaRuntime;
use ruya::util::cli::Args;
use std::time::Instant;

/// Paper Table II means for the comparison banner.
const PAPER_CP: [f64; 3] = [8.735, 16.487, 23.629];
const PAPER_RUYA: [f64; 3] = [3.307, 6.627, 11.631];
const PAPER_Q: [f64; 3] = [0.379, 0.402, 0.492];

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let default_backend = if XlaRuntime::artifacts_available() { "xla" } else { "native" };
    let backend_name = args.opt_or("backend", default_backend);
    let default_reps = if backend_name == "xla" { 20 } else { 200 };
    let cfg = ExperimentConfig {
        reps: args.opt_usize("reps", default_reps),
        seed: args.opt_u64("seed", 0xC0FFEE),
        curve_len: 48,
    };

    let threads = args.opt_threads();
    println!(
        "=== Ruya full reproduction: 16 jobs x 2 methods x {} reps, backend {backend_name}, \
         {threads} thread(s) ===\n",
        cfg.reps
    );
    let runner = ExperimentRunner::new(backend_factory_by_name(&backend_name)?)
        .with_threads(threads);

    // Tables I and III (profiling phase).
    let summaries = runner.profile_all(cfg.seed);
    println!("Table I: Determined Job Memory Requirement\n{}", report::render_table1(&summaries));
    println!("Table III: Memory Profiling Time\n{}", report::render_table3(&summaries));

    // Table II (the search experiment).
    let t0 = Instant::now();
    let result = runner.run_table2(&cfg)?;
    let wall = t0.elapsed();
    println!("Table II: iterations to find a configuration with cost c\n{}", report::render_table2(&result));

    println!("paper-vs-measured (means):");
    println!("  {:22} {:>8} {:>8} {:>8}", "", "c<=1.2", "c<=1.1", "c=1.0");
    println!(
        "  {:22} {:>8.3} {:>8.3} {:>8.3}",
        "CherryPick (paper)", PAPER_CP[0], PAPER_CP[1], PAPER_CP[2]
    );
    println!(
        "  {:22} {:>8.3} {:>8.3} {:>8.3}",
        "CherryPick (measured)",
        result.mean_cherrypick[0],
        result.mean_cherrypick[1],
        result.mean_cherrypick[2]
    );
    println!(
        "  {:22} {:>8.3} {:>8.3} {:>8.3}",
        "Ruya (paper)", PAPER_RUYA[0], PAPER_RUYA[1], PAPER_RUYA[2]
    );
    println!(
        "  {:22} {:>8.3} {:>8.3} {:>8.3}",
        "Ruya (measured)", result.mean_ruya[0], result.mean_ruya[1], result.mean_ruya[2]
    );
    println!(
        "  {:22} {:>7.1}% {:>7.1}% {:>7.1}%",
        "quotient (paper)",
        PAPER_Q[0] * 100.0,
        PAPER_Q[1] * 100.0,
        PAPER_Q[2] * 100.0
    );
    println!(
        "  {:22} {:>7.1}% {:>7.1}% {:>7.1}%",
        "quotient (measured)",
        result.mean_quotient[0] * 100.0,
        result.mean_quotient[1] * 100.0,
        result.mean_quotient[2] * 100.0
    );

    let searches = 2 * 16 * cfg.reps;
    println!(
        "\n{} searches ({} simulated cluster executions) in {:.1} s — {:.1} ms per search",
        searches,
        searches * runner.space.len(),
        wall.as_secs_f64(),
        wall.as_secs_f64() * 1000.0 / searches as f64
    );

    // Figures 4 and 5.
    let n = result.jobs.len() as f64;
    let avg = |f: &dyn Fn(&ruya::coordinator::JobComparison) -> &Vec<f64>| {
        let mut acc = vec![0.0; cfg.curve_len];
        for j in &result.jobs {
            for (i, v) in f(j).iter().take(cfg.curve_len).enumerate() {
                acc[i] += v / n;
            }
        }
        acc
    };
    let fig4 = report::render_series(
        &avg(&|j| &j.cherrypick.best_curve),
        &avg(&|j| &j.ruya.best_curve),
        "Fig 4: best-found normalized cost per iteration",
    );
    let fig5 = report::render_series(
        &avg(&|j| &j.cherrypick.cum_curve),
        &avg(&|j| &j.ruya.cum_curve),
        "Fig 5: cumulative normalized execution cost",
    );
    println!("{fig4}");
    println!("{fig5}");

    if let Some(dir) = args.opt("out") {
        std::fs::create_dir_all(dir)?;
        std::fs::write(format!("{dir}/table1.md"), report::render_table1(&summaries))?;
        std::fs::write(format!("{dir}/table3.md"), report::render_table3(&summaries))?;
        std::fs::write(format!("{dir}/table2.md"), report::render_table2(&result))?;
        std::fs::write(format!("{dir}/table2.json"), report::experiment_to_json(&result))?;
        std::fs::write(format!("{dir}/fig4.dat"), fig4)?;
        std::fs::write(format!("{dir}/fig5.dat"), fig5)?;
        println!("results written to {dir}/");
    }
    Ok(())
}
