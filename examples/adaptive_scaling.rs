//! Adaptive scaling: the §IV-E scenario that motivates Ruya over
//! CherryPick for *growing datasets*.
//!
//! A recurring job's input grows month over month. Ruya profiled the job
//! once; its linear memory model re-extrapolates the requirement for each
//! new input size and re-splits the search space — no new profiling, no
//! search restart. CherryPick's observations, tied to the old cost
//! surface, would have to be discarded ("would effectively need to
//! restart the profiling process once these key input dataset
//! characteristics change").
//!
//! Run: `cargo run --release --example adaptive_scaling`

use ruya::coordinator::{ExperimentRunner, SearchPlan};
use ruya::workload::{evaluation_jobs, JobCostTable, JobInstance};

fn main() -> anyhow::Result<()> {
    let runner = ExperimentRunner::native();

    // Base job: K-Means, profiled ONCE at 100.8 GB.
    let base = evaluation_jobs()
        .into_iter()
        .find(|j| j.label() == "K-Means Spark huge")
        .unwrap();
    let profile = runner.profile_job(&base, 3);
    println!(
        "profiled {} once: {} ({:.0} s)\n",
        base.label(),
        profile.table1_cell,
        profile.profiling_time_s
    );

    println!(
        "{:>10} {:>12} {:>10} {:>14} {:>14}",
        "input_gb", "requirement", "priority", "ruya_iters", "cherrypick"
    );

    // The dataset grows 30% each period; the SAME memory model adapts.
    // Each period averages over several search repetitions (fresh random
    // initializations), like the paper's protocol.
    const REPS: u64 = 20;
    let mut cp_total = 0.0;
    let mut ruya_total = 0.0;
    for period in 0..6 {
        let growth = 1.3f64.powi(period);
        let job = JobInstance {
            input_gb: base.input_gb * growth,
            job_id: base.job_id * 100 + period as u64,
            ..base
        };
        let req = profile.model.estimate_requirement_gb(job.input_gb);
        let plan = runner.planner.plan(&profile.model, job.input_gb, &runner.space);
        let table = JobCostTable::build(&runner.sim, &job, &runner.space);

        let mut ruya_iters = 0.0;
        let mut cp_iters = 0.0;
        for rep in 0..REPS {
            let seed = 1000 * (period as u64 + 1) + rep;
            let ruya = runner.run_one(&table, &plan, seed)?;
            let cp = runner.run_one(&table, &SearchPlan::unpartitioned(&runner.space), seed)?;
            ruya_iters += ruya.first_within(1.0 + 1e-9).unwrap() as f64 / REPS as f64;
            cp_iters += cp.first_within(1.0 + 1e-9).unwrap() as f64 / REPS as f64;
        }
        ruya_total += ruya_iters;
        cp_total += cp_iters;

        println!(
            "{:>10.1} {:>9.0} GB {:>7}/{:<2} {:>14.2} {:>14.2}",
            job.input_gb,
            req,
            plan.phases[0].len(),
            runner.space.len(),
            ruya_iters,
            cp_iters
        );
    }

    println!(
        "\ntotal cluster executions over 6 growth periods: Ruya {ruya_total:.1} vs CherryPick-restart {cp_total:.1} ({:.0}%)",
        100.0 * ruya_total as f64 / cp_total as f64
    );
    println!("(CherryPick must restart its search each period: its old observations describe a different cost surface)");
    Ok(())
}
