"""AOT bridge tests: artifact generation, portability checks, shape
metadata and determinism."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model

REPO_PY = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestHloText:
    @pytest.fixture(scope="class")
    def ei_text(self):
        return aot.to_hlo_text(model.gp_ei_entry, model.gp_ei_shapes())

    @pytest.fixture(scope="class")
    def nll_text(self):
        return aot.to_hlo_text(model.gp_nll_entry, model.gp_nll_shapes())

    def test_ei_is_hlo_module(self, ei_text):
        assert ei_text.startswith("HloModule")
        assert "ENTRY" in ei_text

    def test_ei_is_portable(self, ei_text):
        # No lapack/Mosaic custom-calls, no chlo remnants: the whole point
        # of the hand-rolled linalg in model.py.
        aot.check_portable("gp_ei", ei_text)

    def test_nll_is_portable(self, nll_text):
        aot.check_portable("gp_nll", nll_text)

    def test_ei_has_expected_parameters(self, ei_text):
        # 6 parameters with the frozen shapes must appear in the entry
        # computation signature.
        assert f"f32[{model.N_OBS},{model.N_FEATURES}]" in ei_text
        assert f"f32[{model.N_CANDIDATES},{model.N_FEATURES}]" in ei_text
        assert "f32[3]" in ei_text

    def test_nll_has_grid_parameter(self, nll_text):
        assert f"f32[{model.N_GRID},3]" in nll_text

    def test_lowering_is_deterministic(self, ei_text):
        again = aot.to_hlo_text(model.gp_ei_entry, model.gp_ei_shapes())
        assert again == ei_text

    def test_check_portable_rejects_custom_calls(self):
        bad = "HloModule m\n %x = f32[2] custom-call(f32[2] %p), target=lapack_spotrf\n"
        with pytest.raises(RuntimeError, match="custom-call"):
            aot.check_portable("bad", bad)


class TestAotCli:
    def test_writes_artifacts_and_meta(self, tmp_path):
        out = tmp_path / "artifacts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
            check=True,
            cwd=REPO_PY,
            capture_output=True,
        )
        meta = json.loads((out / "meta.json").read_text())
        assert meta["n_obs"] == model.N_OBS
        assert meta["n_obs_tiers"] == list(model.N_OBS_TIERS)
        assert meta["n_candidates"] == model.N_CANDIDATES
        for n in model.N_OBS_TIERS:
            assert (out / f"gp_ei_n{n}.hlo.txt").exists()
            assert (out / f"gp_nll_n{n}.hlo.txt").exists()
            ei = meta["artifacts"][f"gp_ei_n{n}"]
            assert ei["args"][0] == [n, model.N_FEATURES]
            assert ei["args"][5] == [3]
            assert (
                ei["hlo_bytes"] == (out / f"gp_ei_n{n}.hlo.txt").stat().st_size
            )


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
