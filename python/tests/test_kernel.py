"""Layer-1 correctness: the Pallas Matern-5/2 kernel vs the pure-jnp
oracle — the core correctness signal for the compiled hot path.

Hypothesis sweeps shapes, dtypes, block sizes and hyperparameters; the
pallas_call runs in interpret mode exactly as it does inside the AOT
artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matern import matern52_gram
from compile.kernels.ref import matern52_gram_ref, pairwise_sqdist_ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0, dtype=np.float32):
    return (scale * np.random.RandomState(seed).rand(*shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Directed unit tests
# ---------------------------------------------------------------------------

class TestMaternDirected:
    def test_matches_ref_basic(self):
        a = rand((16, 6), 0)
        b = rand((24, 6), 1)
        k = matern52_gram(a, b, 0.5, 2.0)
        kr = matern52_gram_ref(a, b, 0.5, 2.0)
        np.testing.assert_allclose(k, kr, rtol=1e-5, atol=1e-6)

    def test_zero_distance_gives_variance(self):
        a = rand((8, 6), 2)
        k = matern52_gram(a, a, 0.7, 3.25)
        np.testing.assert_allclose(np.diag(k), 3.25, rtol=1e-6)

    def test_symmetry_on_same_inputs(self):
        a = rand((10, 6), 3)
        k = np.asarray(matern52_gram(a, a, 0.9, 1.0))
        np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)

    def test_values_in_range(self):
        # 0 < k <= variance for any distance
        a = rand((12, 6), 4, scale=3.0)
        b = rand((20, 6), 5, scale=3.0)
        k = np.asarray(matern52_gram(a, b, 0.4, 1.5))
        assert (k > 0.0).all()
        assert (k <= 1.5 + 1e-6).all()

    def test_decreases_with_distance(self):
        a = np.zeros((1, 6), np.float32)
        dists = np.linspace(0.1, 5.0, 30, dtype=np.float32)
        b = np.zeros((30, 6), np.float32)
        b[:, 0] = dists
        k = np.asarray(matern52_gram(a, b, 1.0, 1.0))[0]
        assert (np.diff(k) < 0).all(), "kernel must decay monotonically"

    def test_lengthscale_scaling_identity(self):
        # k(r; l) == k(r/l; 1): scaling inputs by l equals lengthscale l.
        a = rand((6, 6), 6)
        b = rand((9, 6), 7)
        ls = 0.35
        k1 = matern52_gram(a, b, ls, 1.0)
        k2 = matern52_gram(a / ls, b / ls, 1.0, 1.0)
        np.testing.assert_allclose(k1, k2, rtol=1e-4, atol=1e-6)

    def test_gram_is_positive_semidefinite(self):
        a = rand((20, 6), 8)
        k = np.asarray(matern52_gram(a, a, 0.6, 1.0), dtype=np.float64)
        evals = np.linalg.eigvalsh((k + k.T) / 2)
        assert evals.min() > -1e-5, f"min eigenvalue {evals.min()}"

    def test_single_row_inputs(self):
        a = rand((1, 6), 9)
        b = rand((1, 6), 10)
        k = matern52_gram(a, b, 0.5, 1.0)
        kr = matern52_gram_ref(a, b, 0.5, 1.0)
        np.testing.assert_allclose(k, kr, rtol=1e-5, atol=1e-6)

    def test_non_multiple_of_block_shapes(self):
        # 7 and 13 are coprime to the 4/8 blocks: exercises padding+slice.
        a = rand((7, 6), 11)
        b = rand((13, 6), 12)
        k = matern52_gram(a, b, 0.5, 1.0, block_n=4, block_m=8)
        kr = matern52_gram_ref(a, b, 0.5, 1.0)
        np.testing.assert_allclose(k, kr, rtol=1e-5, atol=1e-6)

    def test_block_size_invariance(self):
        a = rand((32, 6), 13)
        b = rand((48, 6), 14)
        k1 = matern52_gram(a, b, 0.8, 1.2, block_n=8, block_m=16)
        k2 = matern52_gram(a, b, 0.8, 1.2, block_n=32, block_m=64)
        np.testing.assert_allclose(k1, k2, rtol=1e-6, atol=1e-7)

    def test_aot_shapes(self):
        # The exact shapes frozen into the artifact.
        a = rand((64, 6), 15)
        b = rand((128, 6), 16)
        k = matern52_gram(a, b, 0.5, 1.0)
        kr = matern52_gram_ref(a, b, 0.5, 1.0)
        assert k.shape == (64, 128)
        np.testing.assert_allclose(k, kr, rtol=1e-5, atol=1e-6)

    def test_sqdist_ref_matches_direct(self):
        a = rand((5, 6), 17)
        b = rand((8, 6), 18)
        d2 = np.asarray(pairwise_sqdist_ref(a, b))
        direct = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        np.testing.assert_allclose(d2, direct, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------

shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=40),  # n
    st.integers(min_value=1, max_value=40),  # m
    st.integers(min_value=1, max_value=8),   # d
)


@settings(max_examples=30, deadline=None)
@given(
    shape=shape_strategy,
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    ls=st.floats(min_value=0.05, max_value=5.0),
    var=st.floats(min_value=0.1, max_value=10.0),
)
def test_hypothesis_matches_ref(shape, seed, ls, var):
    n, m, d = shape
    a = rand((n, d), seed)
    b = rand((m, d), seed + 1)
    k = matern52_gram(a, b, ls, var)
    kr = matern52_gram_ref(a, b, ls, var)
    np.testing.assert_allclose(k, kr, rtol=2e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=30),
    bn=st.sampled_from([1, 2, 4, 8, 16, 32]),
    bm=st.sampled_from([1, 2, 4, 8, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_block_invariance(n, bn, bm, seed):
    a = rand((n, 6), seed)
    b = rand((n + 3, 6), seed + 1)
    k1 = matern52_gram(a, b, 0.5, 1.0, block_n=bn, block_m=bm)
    kr = matern52_gram_ref(a, b, 0.5, 1.0)
    np.testing.assert_allclose(k1, kr, rtol=2e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_dtype_inputs_accepted(dtype, seed):
    # The kernel casts everything to f32 internally; f64 inputs must give
    # the same (f32) answer.
    a = rand((9, 6), seed, dtype=dtype)
    b = rand((11, 6), seed + 1, dtype=dtype)
    k = matern52_gram(a, b, 0.5, 1.0)
    assert k.dtype == jnp.float32
    kr = matern52_gram_ref(
        a.astype(np.float32), b.astype(np.float32), 0.5, 1.0
    )
    np.testing.assert_allclose(k, kr, rtol=2e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_psd(seed):
    a = rand((16, 6), seed, scale=2.0)
    k = np.asarray(matern52_gram(a, a, 0.5, 1.0), dtype=np.float64)
    evals = np.linalg.eigvalsh((k + k.T) / 2)
    assert evals.min() > -1e-5


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
