"""Layer-2 correctness: the portable GP building blocks and the full
gp_ei / gp_nll entry points vs direct numpy linear algebra.

The numpy reference uses np.linalg (LAPACK) — precisely the dependency the
artifact cannot contain — so agreement here validates the hand-rolled
fori_loop Cholesky/solves that DO ship in the artifact.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import matern52_gram_ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0):
    return (scale * np.random.RandomState(seed).rand(*shape)).astype(np.float32)


def spd_matrix(n, seed):
    a = np.random.RandomState(seed).randn(n, n).astype(np.float32)
    return a @ a.T + n * np.eye(n, dtype=np.float32)


# ---------------------------------------------------------------------------
# Portable linear algebra vs numpy
# ---------------------------------------------------------------------------

class TestPortableLinalg:
    @pytest.mark.parametrize("n", [1, 2, 5, 16, 64])
    def test_cholesky_matches_numpy(self, n):
        a = spd_matrix(n, n)
        l = np.asarray(model.chol_lower(jnp.asarray(a)))
        lr = np.linalg.cholesky(a.astype(np.float64))
        np.testing.assert_allclose(l, lr, rtol=1e-3, atol=1e-4)

    def test_cholesky_is_lower_triangular(self):
        a = spd_matrix(12, 3)
        l = np.asarray(model.chol_lower(jnp.asarray(a)))
        assert np.allclose(np.triu(l, 1), 0.0)

    @pytest.mark.parametrize("rhs", ["vector", "matrix"])
    def test_forward_substitution(self, rhs):
        n = 10
        l = np.tril(np.random.RandomState(0).rand(n, n).astype(np.float32)) + np.eye(
            n, dtype=np.float32
        )
        b = rand((n,) if rhs == "vector" else (n, 7), 1)
        z = np.asarray(model.solve_lower(jnp.asarray(l), jnp.asarray(b)))
        np.testing.assert_allclose(l @ z, b, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("rhs", ["vector", "matrix"])
    def test_backward_substitution(self, rhs):
        n = 10
        l = np.tril(np.random.RandomState(2).rand(n, n).astype(np.float32)) + np.eye(
            n, dtype=np.float32
        )
        b = rand((n,) if rhs == "vector" else (n, 5), 3)
        x = np.asarray(model.solve_upper_t(jnp.asarray(l), jnp.asarray(b)))
        np.testing.assert_allclose(l.T @ x, b, rtol=1e-4, atol=1e-5)

    def test_full_solve_roundtrip(self):
        n = 20
        a = spd_matrix(n, 5)
        b = rand((n,), 6)
        l = model.chol_lower(jnp.asarray(a))
        x = np.asarray(model.solve_upper_t(l, model.solve_lower(l, jnp.asarray(b))))
        np.testing.assert_allclose(a @ x, b, rtol=1e-2, atol=1e-3)


class TestNormCdf:
    def test_matches_math_erf(self):
        xs = np.linspace(-6, 6, 200)
        ours = np.asarray(model.norm_cdf(jnp.asarray(xs, jnp.float32)))
        exact = np.array([0.5 * (1 + math.erf(x / math.sqrt(2))) for x in xs])
        np.testing.assert_allclose(ours, exact, atol=2e-7)

    def test_pdf_integrates_to_cdf_slope(self):
        x = jnp.asarray(np.linspace(-3, 3, 100), jnp.float32)
        pdf = np.asarray(model.norm_pdf(x))
        cdf = np.asarray(model.norm_cdf(x))
        slope = np.gradient(cdf, np.asarray(x))
        np.testing.assert_allclose(pdf, slope, atol=5e-3)


# ---------------------------------------------------------------------------
# GP posterior vs direct numpy GP
# ---------------------------------------------------------------------------

def numpy_gp(x, y, xc, ls, var, noise):
    """Direct (LAPACK) masked-free GP for cross-checking."""
    k = np.asarray(matern52_gram_ref(x, x, ls, var), np.float64)
    k += (noise + model.JITTER) * np.eye(len(x))
    ks = np.asarray(matern52_gram_ref(xc, x, ls, var), np.float64)
    kinv_y = np.linalg.solve(k, y.astype(np.float64))
    mu = ks @ kinv_y
    v = np.linalg.solve(k, ks.T)
    var_post = var - np.einsum("ij,ji->i", ks, v)
    return mu, np.maximum(var_post, 0.0)


class TestGpPosterior:
    def _run(self, n, m, seed, hyp):
        x = rand((n, 6), seed)
        y = rand((n,), seed + 1, scale=3.0)
        xc = rand((m, 6), seed + 2)
        mask = jnp.ones(n, jnp.float32)
        cmask = jnp.ones(m, jnp.float32)
        ei, mu, var = model.gp_ei(
            jnp.asarray(x), jnp.asarray(y), mask, jnp.asarray(xc), cmask,
            jnp.asarray(hyp, jnp.float32),
        )
        mu_ref, var_ref = numpy_gp(x, y, xc, *hyp)
        return np.asarray(ei), np.asarray(mu), np.asarray(var), mu_ref, var_ref

    @pytest.mark.parametrize("n,m", [(3, 5), (10, 20), (30, 69)])
    def test_posterior_matches_numpy(self, n, m):
        ei, mu, var, mu_ref, var_ref = self._run(n, m, 42, (0.5, 1.0, 1e-3))
        np.testing.assert_allclose(mu, mu_ref, rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(var, var_ref, rtol=5e-2, atol=1e-2)

    def test_ei_nonnegative_and_finite(self):
        ei, *_ = self._run(8, 16, 7, (0.8, 2.0, 1e-2))
        assert np.isfinite(ei).all()
        assert (ei >= 0.0).all()

    def test_padding_invariance(self):
        """The core masking contract: results must not depend on how much
        padding is appended past the mask."""
        n, m = 6, 9
        x = rand((n, 6), 11)
        y = rand((n,), 12, scale=2.0)
        xc = rand((m, 6), 13)
        hyp = jnp.asarray([0.5, 1.0, 1e-3], jnp.float32)

        def padded(n_pad, m_pad):
            xp = np.zeros((n_pad, 6), np.float32)
            xp[:n] = x
            yp = np.zeros(n_pad, np.float32)
            yp[:n] = y
            mask = np.zeros(n_pad, np.float32)
            mask[:n] = 1.0
            xcp = np.zeros((m_pad, 6), np.float32)
            xcp[:m] = xc
            cm = np.zeros(m_pad, np.float32)
            cm[:m] = 1.0
            ei, mu, var = model.gp_ei(
                jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mask),
                jnp.asarray(xcp), jnp.asarray(cm), hyp,
            )
            return np.asarray(ei)[:m], np.asarray(mu)[:m], np.asarray(var)[:m]

        e1, m1, v1 = padded(n, m)
        e2, m2, v2 = padded(model.N_OBS, model.N_CANDIDATES)
        np.testing.assert_allclose(m1, m2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(v1, v2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(e1, e2, rtol=1e-3, atol=1e-5)

    def test_interpolation_at_low_noise(self):
        n = 5
        x = rand((n, 6), 21)
        y = rand((n,), 22, scale=2.0)
        mask = jnp.ones(n, jnp.float32)
        _, mu, var = model.gp_ei(
            jnp.asarray(x), jnp.asarray(y), mask, jnp.asarray(x),
            jnp.ones(n, jnp.float32), jnp.asarray([0.5, 1.0, 1e-6], jnp.float32),
        )
        np.testing.assert_allclose(np.asarray(mu), y, atol=5e-3)
        assert (np.asarray(var) < 1e-2).all()

    def test_cmask_zeroes_ei_only(self):
        n, m = 4, 6
        x = rand((n, 6), 31)
        y = rand((n,), 32)
        xc = rand((m, 6), 33)
        cm = np.ones(m, np.float32)
        cm[2] = 0.0
        ei, mu, var = model.gp_ei(
            jnp.asarray(x), jnp.asarray(y), jnp.ones(n, jnp.float32),
            jnp.asarray(xc), jnp.asarray(cm),
            jnp.asarray([0.5, 1.0, 1e-3], jnp.float32),
        )
        assert float(ei[2]) == 0.0
        assert np.isfinite(float(mu[2]))  # posterior still computed


class TestExpectedImprovement:
    def test_closed_form_values(self):
        # EI(best=1, mu=0, var=1) for minimization: delta=1, z=1
        ei = float(
            model.expected_improvement(
                jnp.asarray([0.0]), jnp.asarray([1.0]), jnp.asarray(1.0)
            )[0]
        )
        exact = 1.0 * 0.8413447 + 1.0 * 0.2419707
        assert abs(ei - exact) < 1e-4

    def test_zero_at_dominated_point_zero_sigma(self):
        ei = float(
            model.expected_improvement(
                jnp.asarray([2.0]), jnp.asarray([0.0]), jnp.asarray(1.0)
            )[0]
        )
        assert ei == 0.0

    def test_monotone_in_sigma(self):
        sigmas = np.linspace(0.01, 2.0, 20, dtype=np.float32)
        ei = np.asarray(
            model.expected_improvement(
                jnp.full(20, 1.5), jnp.asarray(sigmas**2), jnp.asarray(1.0)
            )
        )
        assert (np.diff(ei) > 0).all(), "EI must grow with uncertainty"

    def test_monotone_in_mu(self):
        mus = np.linspace(-1.0, 3.0, 20, dtype=np.float32)
        ei = np.asarray(
            model.expected_improvement(
                jnp.asarray(mus), jnp.full(20, 0.25), jnp.asarray(1.0)
            )
        )
        assert (np.diff(ei) < 0).all(), "EI must shrink as mean worsens"


# ---------------------------------------------------------------------------
# Marginal likelihood
# ---------------------------------------------------------------------------

def numpy_nll(x, y, ls, var, noise):
    k = np.asarray(matern52_gram_ref(x, x, ls, var), np.float64)
    k += (noise + model.JITTER) * np.eye(len(x))
    sign, logdet = np.linalg.slogdet(k)
    assert sign > 0
    kinv_y = np.linalg.solve(k, y.astype(np.float64))
    return 0.5 * (y @ kinv_y + logdet + len(x) * np.log(2 * np.pi))


class TestNll:
    @pytest.mark.parametrize("n", [2, 8, 24])
    def test_matches_numpy(self, n):
        x = rand((n, 6), n)
        y = rand((n,), n + 1, scale=2.0)
        hyp = jnp.asarray([0.6, 1.5, 1e-2], jnp.float32)
        ours = float(
            model.gp_nll_single(
                jnp.asarray(x), jnp.asarray(y), jnp.ones(n, jnp.float32), hyp
            )
        )
        ref = numpy_nll(x, y, 0.6, 1.5, 1e-2)
        assert abs(ours - ref) < max(0.02 * abs(ref), 0.05), f"{ours} vs {ref}"

    def test_grid_matches_singles(self):
        n = 6
        x = rand((n, 6), 51)
        y = rand((n,), 52)
        mask = jnp.ones(n, jnp.float32)
        grid = jnp.asarray(
            [[0.3, 1.0, 1e-3], [0.6, 2.0, 1e-2], [1.2, 0.5, 1e-1]], jnp.float32
        )
        batch = np.asarray(model.gp_nll(jnp.asarray(x), jnp.asarray(y), mask, grid))
        for i in range(3):
            single = float(
                model.gp_nll_single(jnp.asarray(x), jnp.asarray(y), mask, grid[i])
            )
            assert abs(batch[i] - single) < 1e-4

    def test_mask_padding_invariance(self):
        n = 5
        x = rand((n, 6), 61)
        y = rand((n,), 62)
        hyp = jnp.asarray([0.5, 1.0, 1e-3], jnp.float32)
        direct = float(
            model.gp_nll_single(jnp.asarray(x), jnp.asarray(y), jnp.ones(n), hyp)
        )
        xp = np.zeros((model.N_OBS, 6), np.float32)
        xp[:n] = x
        yp = np.zeros(model.N_OBS, np.float32)
        yp[:n] = y
        mask = np.zeros(model.N_OBS, np.float32)
        mask[:n] = 1.0
        padded = float(
            model.gp_nll_single(
                jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mask), hyp
            )
        )
        assert abs(direct - padded) < 1e-3, f"{direct} vs {padded}"


# ---------------------------------------------------------------------------
# Hypothesis sweeps over the full entry point
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=20),
    m=st.integers(min_value=1, max_value=30),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    ls=st.floats(min_value=0.1, max_value=2.0),
    noise=st.floats(min_value=1e-5, max_value=0.1),
)
def test_hypothesis_gp_ei_well_posed(n, m, seed, ls, noise):
    x = rand((n, 6), seed)
    y = rand((n,), seed + 1, scale=4.0)
    xc = rand((m, 6), seed + 2)
    ei, mu, var = model.gp_ei(
        jnp.asarray(x), jnp.asarray(y), jnp.ones(n, jnp.float32),
        jnp.asarray(xc), jnp.ones(m, jnp.float32),
        jnp.asarray([ls, 1.0, noise], jnp.float32),
    )
    ei, mu, var = np.asarray(ei), np.asarray(mu), np.asarray(var)
    assert np.isfinite(ei).all() and np.isfinite(mu).all() and np.isfinite(var).all()
    assert (ei >= 0.0).all()
    assert (var >= 0.0).all()
    # Posterior variance can never exceed the prior variance (+fp slack).
    assert (var <= 1.0 + 1e-3).all()


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
