"""AOT bridge: lower the Layer-2 GP computations to HLO *text* artifacts.

HLO text (not a serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the rust `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); the rust binary is fully
self-contained afterwards.  Alongside the HLO we emit ``meta.json`` with
the frozen shapes and argument order so the rust runtime can validate its
marshaling against the artifact generation.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, shapes) -> str:
    """Lower a jittable fn at the given ShapeDtypeStructs to HLO text.

    return_tuple=True so the rust side always unwraps a tuple, regardless
    of arity.
    """
    lowered = jax.jit(fn).lower(*shapes)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


FORBIDDEN = ("custom-call", "chlo.", "erf")


def check_portable(name: str, text: str) -> None:
    """The artifact must be runnable by the bare 0.5.1 CPU PJRT client:
    no lapack/Mosaic custom-calls, no chlo remnants."""
    lower = text.lower()
    for needle in FORBIDDEN:
        if needle in lower:
            lines = [l for l in lower.splitlines() if needle in l][:3]
            raise RuntimeError(
                f"artifact {name} is not portable: contains {needle!r}: {lines}"
            )


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    # One (gp_ei, gp_nll) pair per observation tier: the rust runtime
    # dispatches each decision to the smallest tier that fits, avoiding
    # the O(N^3) padded factorization cost at small fill levels (§Perf).
    entries = {}
    for n in model.N_OBS_TIERS:
        entries[f"gp_ei_n{n}"] = (model.gp_ei_entry, model.gp_ei_shapes(n))
        entries[f"gp_nll_n{n}"] = (model.gp_nll_entry, model.gp_nll_shapes(n))

    meta = {
        "n_obs": model.N_OBS,
        "n_obs_tiers": list(model.N_OBS_TIERS),
        "n_features": model.N_FEATURES,
        "n_candidates": model.N_CANDIDATES,
        "n_grid": model.N_GRID,
        "artifacts": {},
    }

    for name, (fn, shapes) in entries.items():
        text = to_hlo_text(fn, shapes)
        check_portable(name, text)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [list(s.shape) for s in shapes],
            "hlo_bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()
