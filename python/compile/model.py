"""Layer-2 JAX model: masked Gaussian-process regression + expected
improvement -- the per-iteration decision computation of Ruya's (and
CherryPick's) Bayesian-optimized search.

Two entry points, AOT-lowered to HLO text by aot.py and executed from the
rust coordinator on every search iteration:

  gp_ei(X, y, mask, Xc, cmask, hyp)   -> (ei, mu, var)
  gp_nll(X, y, mask, grid)            -> nll

Shapes are fixed at AOT time (N observations padded, M candidates padded,
H hyperparameter grid rows); the live fill level is communicated through
the 0/1 masks, so ONE compiled executable serves every iteration of every
search.

Portability constraints (see /opt/xla-example/README.md): the HLO must be
runnable by xla_extension 0.5.1's CPU PJRT client, which cannot execute
jax's CPU lowerings of lapack-backed ops (custom-calls) nor chlo.erf.
Cholesky, the triangular solves and the normal CDF are therefore written
out in plain jnp ops (fori_loop + dynamic_update_slice + exp/sqrt), which
lower to self-contained HLO.  At N=64 the loop-based factorization is a
few hundred microseconds -- far below the cost of a cluster run it decides
about, and amortized further by the rust runtime reusing the executable.
"""

import jax
import jax.numpy as jnp

from .kernels.matern import matern52_gram

# AOT shapes.  N >= max search length (the evaluation space has 69 configs
# and searches converge in far fewer iterations); M >= |space|; H is the
# hyperparameter-selection grid.
#
# N is emitted in TIERS: most decisions happen at small observation counts
# (searches find the optimum in ~7-15 executions), and the padded Cholesky
# while-loop costs O(N^3) regardless of the live fill, so the rust runtime
# dispatches each call to the smallest tier that fits (§Perf).
N_OBS_TIERS = (16, 32, 64)
N_OBS = N_OBS_TIERS[-1]
N_FEATURES = 6
N_CANDIDATES = 128
N_GRID = 32

# Jitter added to the active diagonal on top of the modeled noise, for
# Cholesky robustness at f32.
JITTER = 1e-6

SQRT2 = 1.4142135623730951
INV_SQRT_2PI = 0.3989422804014327


# ---------------------------------------------------------------------------
# Portable linear algebra (plain-HLO Cholesky and triangular solves)
# ---------------------------------------------------------------------------

def chol_lower(a):
    """Cholesky factor L (lower) of SPD ``a`` [n, n] via a column-by-column
    Cholesky-Crout fori_loop.  Lowers to a self-contained HLO while loop."""
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, l):
        # s = a[:, j] - (L L^T)[:, j]; columns >= j of L are still zero, so
        # the matvec only sums k < j as required.
        s = a[:, j] - l @ l[j, :]
        d = jnp.sqrt(jnp.maximum(s[j], 1e-30))
        col = jnp.where(idx > j, s / d, 0.0)
        col = jnp.where(idx == j, d, col)
        return l.at[:, j].set(col)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a))


def solve_lower(l, b):
    """Forward substitution: solve L z = b for lower-triangular L.

    l: [n, n], b: [n] or [n, m] -> same shape as b.
    """
    vector = b.ndim == 1
    bm = b[:, None] if vector else b
    n = l.shape[0]

    def body(i, z):
        zi = (bm[i, :] - l[i, :] @ z) / l[i, i]
        return z.at[i, :].set(zi)

    z = jax.lax.fori_loop(0, n, body, jnp.zeros_like(bm))
    return z[:, 0] if vector else z


def solve_upper_t(l, b):
    """Backward substitution: solve L^T x = b for lower-triangular L."""
    vector = b.ndim == 1
    bm = b[:, None] if vector else b
    n = l.shape[0]

    def body(k, x):
        i = n - 1 - k
        xi = (bm[i, :] - l[:, i] @ x) / l[i, i]
        return x.at[i, :].set(xi)

    x = jax.lax.fori_loop(0, n, body, jnp.zeros_like(bm))
    return x[:, 0] if vector else x


# ---------------------------------------------------------------------------
# Portable normal CDF/PDF (no chlo.erf in the artifact)
# ---------------------------------------------------------------------------

def _erf_approx(x):
    """Abramowitz & Stegun 7.1.26 rational erf approximation, |err|<1.5e-7.

    Built only from abs/exp/polynomials so it lowers to plain HLO.
    """
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    poly = t * (
        0.254829592
        + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429)))
    )
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def norm_cdf(x):
    return 0.5 * (1.0 + _erf_approx(x / SQRT2))


def norm_pdf(x):
    return INV_SQRT_2PI * jnp.exp(-0.5 * x * x)


# ---------------------------------------------------------------------------
# Masked GP posterior + expected improvement
# ---------------------------------------------------------------------------

def _masked_gram(x, mask, ls, var, noise):
    """Gram matrix of the active observations, padded rows replaced by
    identity rows so the factorization stays well-posed at any fill level.

    Active block:   K_aa + (noise + jitter) I
    Padded block:   I   (and zero cross terms)
    """
    n = x.shape[0]
    k = matern52_gram(x, x, ls, var)
    mm = mask[:, None] * mask[None, :]
    eye = jnp.eye(n, dtype=x.dtype)
    return k * mm + eye * ((noise + JITTER) * mask + (1.0 - mask))


def gp_fit(x, y, mask, hyp):
    """Factorize the masked training Gram and precompute alpha = K^-1 y.

    Returns (L, alpha).  Masked entries of y are zeroed, so their alpha
    entries are exactly zero and they cannot influence predictions.
    """
    ls, var, noise = hyp[0], hyp[1], hyp[2]
    km = _masked_gram(x, mask, ls, var, noise)
    l = chol_lower(km)
    ym = y * mask
    alpha = solve_upper_t(l, solve_lower(l, ym))
    return l, alpha


def gp_predict(x, mask, hyp, l, alpha, xc):
    """Posterior mean and variance at candidate rows ``xc`` [m, d]."""
    ls, var, noise = hyp[0], hyp[1], hyp[2]
    ks = matern52_gram(xc, x, ls, var) * mask[None, :]  # [m, n]
    mu = ks @ alpha
    v = solve_lower(l, ks.T)  # [n, m]
    var_post = var - jnp.sum(v * v, axis=0)
    # Clamp only against negative cancellation; a genuinely collapsed
    # posterior stays collapsed so expected_improvement's certain-branch
    # (sigma <= 1e-12) is reachable — aligned with the native rust GP
    # (bayesopt/gp.rs VAR_FLOOR). Observation noise is NOT added (we rank
    # configurations by latent cost, as CherryPick does).
    return mu, jnp.maximum(var_post, 0.0)


def expected_improvement(mu, var, best, xi=0.0):
    """EI for *minimization*: E[max(best - Y - xi, 0)], Y ~ N(mu, var)."""
    sigma = jnp.sqrt(var)
    delta = best - mu - xi
    z = delta / jnp.maximum(sigma, 1e-12)
    ei = delta * norm_cdf(z) + sigma * norm_pdf(z)
    return jnp.where(sigma > 1e-12, jnp.maximum(ei, 0.0), jnp.maximum(delta, 0.0))


def gp_ei(x, y, mask, xc, cmask, hyp):
    """The full per-iteration decision computation.

    x: [N, D] observed configurations (feature-encoded, padded)
    y: [N] observed normalized costs (padded with zeros)
    mask: [N] 1.0 for live observations
    xc: [M, D] candidate configurations (padded)
    cmask: [M] 1.0 for candidates still eligible (untried AND inside the
        currently allowed search-space partition -- this is where Ruya's
        priority groups enter, computed by the rust coordinator)
    hyp: [3] (lengthscale, signal variance, noise variance)

    Returns (ei [M], mu [M], var [M]); ei is zeroed outside cmask so the
    coordinator can argmax it directly.
    """
    l, alpha = gp_fit(x, y, mask, hyp)
    mu, var = gp_predict(x, mask, hyp, l, alpha, xc)
    big = jnp.float32(3.4e38)
    best = jnp.min(jnp.where(mask > 0.0, y, big))
    ei = expected_improvement(mu, var, best) * cmask
    return ei, mu, var


# ---------------------------------------------------------------------------
# Hyperparameter selection: negative log marginal likelihood over a grid
# ---------------------------------------------------------------------------

def gp_nll_single(x, y, mask, hyp):
    """NLL of the active observations under hyp = (ls, var, noise).

    Padded rows contribute log(1) = 0 to the determinant and 0 to the
    quadratic form, so the value equals the NLL of the active block alone.
    """
    l, alpha = gp_fit(x, y, mask, hyp)
    ym = y * mask
    quad = 0.5 * jnp.dot(ym, alpha)
    # log det of the masked Gram = 2 sum log diag(L); padded diag entries
    # are exactly 1.
    logdet = jnp.sum(jnp.log(jnp.diagonal(l)))
    nactive = jnp.sum(mask)
    return quad + logdet + 0.5 * nactive * jnp.log(2.0 * jnp.pi)


def gp_nll(x, y, mask, grid):
    """NLL for every hyperparameter triple in ``grid`` [H, 3] -> [H].

    lax.map (sequential scan) rather than vmap: the body contains the
    Pallas kernel and fori_loop factorizations, and scan keeps the lowered
    HLO a single self-contained while loop.
    """
    return jax.lax.map(lambda h: gp_nll_single(x, y, mask, h), grid)


# ---------------------------------------------------------------------------
# AOT wrappers with the frozen artifact shapes
# ---------------------------------------------------------------------------

def gp_ei_entry(x, y, mask, xc, cmask, hyp):
    return gp_ei(x, y, mask, xc, cmask, hyp)


def gp_nll_entry(x, y, mask, grid):
    return (gp_nll(x, y, mask, grid),)


def gp_ei_shapes(n_obs=N_OBS):
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return (
        s((n_obs, N_FEATURES), f32),
        s((n_obs,), f32),
        s((n_obs,), f32),
        s((N_CANDIDATES, N_FEATURES), f32),
        s((N_CANDIDATES,), f32),
        s((3,), f32),
    )


def gp_nll_shapes(n_obs=N_OBS):
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return (
        s((n_obs, N_FEATURES), f32),
        s((n_obs,), f32),
        s((n_obs,), f32),
        s((N_GRID, 3), f32),
    )
