"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

These are the ground truth the Pallas implementations are tested against
(python/tests/test_kernel.py, hypothesis sweeps) and the numerics the
Layer-2 GP model is specified in terms of.
"""

import jax.numpy as jnp

SQRT5 = 2.2360679774997896


def pairwise_sqdist_ref(a, b):
    """Squared euclidean distances between the rows of ``a`` and ``b``.

    a: [n, d], b: [m, d] -> [n, m].  Computed in the same
    ``|a|^2 + |b|^2 - 2 a.b`` form as the kernel so both see identical
    floating point behaviour; clamped at zero against cancellation.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)  # [n, 1]
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T  # [1, m]
    d2 = a2 + b2 - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def matern52_ref(d2, lengthscale, variance):
    """Matern-5/2 covariance from squared distances ``d2``.

    k(r) = var * (1 + sqrt5 r/l + 5 r^2 / (3 l^2)) exp(-sqrt5 r/l)
    """
    d2 = jnp.asarray(d2)
    r = jnp.sqrt(d2) / lengthscale
    poly = 1.0 + SQRT5 * r + (5.0 / 3.0) * d2 / (lengthscale * lengthscale)
    return variance * poly * jnp.exp(-SQRT5 * r)


def matern52_gram_ref(a, b, lengthscale, variance):
    """Full Matern-5/2 Gram matrix between row sets ``a`` [n,d], ``b`` [m,d]."""
    return matern52_ref(pairwise_sqdist_ref(a, b), lengthscale, variance)
