"""Layer-1 Pallas kernel: fused pairwise-distance + Matern-5/2 Gram matrix.

This is the numeric hot spot of the whole Ruya decision path: every search
iteration evaluates the GP over all observations x candidates, and the Gram
construction dominates the FLOP count of a fit at the AOT shapes
(N=64 observations, M=128 candidates, D=6 features).

TPU mapping (see DESIGN.md "Hardware adaptation"): the distance term is
expressed as |a|^2 + |b|^2 - 2 A@B^T so the dominant work is a matmul
(MXU-shaped); tiles are blocked with BlockSpec over (rows, cols) so each
grid step holds an (block_n x d) A-tile, a (block_m x d) B-tile and the
(block_n x block_m) output tile in VMEM.  f32 throughout: the Gram matrix
feeds a Cholesky factorization downstream, which is sensitive to bf16-level
perturbation.

The kernel MUST run with interpret=True in this environment: the CPU PJRT
plugin cannot execute Mosaic custom-calls, and interpret mode lowers the
kernel to plain HLO ops that travel through the AOT text bridge to the rust
runtime unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 2.2360679774997896

# Default tile sizes.  At the AOT shapes a Gram tile is at most
# 32*64*4 B = 8 KiB plus two operand tiles of 32*8*4 B / 64*8*4 B -- far
# inside a TPU core's ~16 MiB VMEM, so a single-pass (no double buffering)
# schedule is the right one; the grid exists to keep the kernel general for
# larger-N variants.
DEFAULT_BLOCK_N = 32
DEFAULT_BLOCK_M = 64


def _matern52_tile_kernel(a_ref, b_ref, hyp_ref, o_ref):
    """One (block_n, block_m) output tile of the Matern-5/2 Gram matrix.

    a_ref: [block_n, d] slab of A rows, VMEM
    b_ref: [block_m, d] slab of B rows, VMEM
    hyp_ref: [1, 2] (lengthscale, variance), replicated to every grid step
    o_ref: [block_n, block_m] output tile
    """
    a = a_ref[...]
    b = b_ref[...]
    ls = hyp_ref[0, 0]
    var = hyp_ref[0, 1]

    # Squared distances via the matmul form; clamp against cancellation so
    # sqrt never sees a negative.
    a2 = jnp.sum(a * a, axis=1, keepdims=True)  # [bn, 1]
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T  # [1, bm]
    d2 = jnp.maximum(a2 + b2 - 2.0 * jnp.dot(a, b.T), 0.0)

    r = jnp.sqrt(d2) / ls
    poly = 1.0 + SQRT5 * r + (5.0 / 3.0) * d2 / (ls * ls)
    o_ref[...] = var * poly * jnp.exp(-SQRT5 * r)


def _pad_rows(x, multiple):
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x
    return jnp.pad(x, ((0, rem), (0, 0)))


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def matern52_gram(
    a,
    b,
    lengthscale,
    variance,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = True,
):
    """Matern-5/2 Gram matrix K[i, j] = k(a_i, b_j) via the Pallas kernel.

    a: [n, d], b: [m, d]; lengthscale/variance are scalars (traced).
    Rows are padded up to the block size and the result is sliced back, so
    any (n, m) works.  Returns [n, m] f32.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    n, d = a.shape
    m, d2 = b.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    bn = min(block_n, max(n, 1))
    bm = min(block_m, max(m, 1))

    ap = _pad_rows(a, bn)
    bp = _pad_rows(b, bm)
    hyp = jnp.stack(
        [jnp.asarray(lengthscale, jnp.float32), jnp.asarray(variance, jnp.float32)]
    ).reshape(1, 2)

    grid = (ap.shape[0] // bn, bp.shape[0] // bm)
    out = pl.pallas_call(
        _matern52_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[0]), jnp.float32),
        interpret=interpret,
    )(ap, bp, hyp)
    return out[:n, :m]
